//! The operator's clock: one [`Clock`] abstraction, two time planes.
//!
//! [`SimClock`] is the classic virtual clock — time is a number the
//! pipeline advances by modeled costs, which makes runs deterministic
//! and orders of magnitude faster than replay.  [`WallClock`] anchors
//! the same timeline to a monotonic wall clock: real time flows on its
//! own, modeled service costs are layered on top through a virtual
//! offset, and scheduled idle gaps can be fast-forwarded so tests and
//! CI runs finish in milliseconds of real time while modeling seconds
//! of load.  Every pipeline loop is written against the trait, so the
//! two planes share one service/queueing semantics.

use std::time::Instant;

/// The pipeline's notion of time (nanoseconds).  All three core
/// operations mirror the original `SimClock` surface exactly; the two
/// waiting primitives exist for the real-time ingest loop.
pub trait Clock: Send {
    /// Current time on this clock's timeline (ns).
    fn now_ns(&self) -> f64;

    /// Account one unit of service: the operator was busy for
    /// `cost_ns` of modeled time.
    fn advance(&mut self, cost_ns: f64);

    /// Begin serving an event that arrived at `arrival_ns`: the clock
    /// jumps to the arrival if it is idle; returns the queueing latency
    /// `l_q` (0 when the operator was idle).
    fn begin_service(&mut self, arrival_ns: f64) -> f64;

    /// Move to a *scheduled* future instant (the next known arrival):
    /// virtual clocks jump, the wall clock fast-forwards its offset.
    /// No-op if `t_ns` is already in the past.
    fn wait_until(&mut self, t_ns: f64);

    /// Wait out an *unscheduled* gap (external source with no known
    /// next arrival): virtual clocks jump by `ns`, the wall clock
    /// really sleeps.
    fn idle(&mut self, ns: f64);

    /// Does real time flow on this clock (i.e. is it a [`WallClock`])?
    fn is_wall(&self) -> bool {
        false
    }
}

/// Instrumentation-only wall-clock stopwatch.
///
/// The clock-discipline rule (enforced by `pallas-audit`) is that
/// `Instant::now` appears nowhere outside this module: *measured* time
/// that shapes results must flow through a [`Clock`], and pure
/// instrumentation — model-build wall time (Fig. 9b), wall throughput
/// of a finished run — must be visibly segregated from it.  `WallTimer`
/// is that segregation: a reading that can be *reported* but never fed
/// back into virtual-clock accounting, because nothing converts it to a
/// timeline position.
#[derive(Debug, Clone, Copy)]
pub struct WallTimer {
    started: Instant,
}

impl WallTimer {
    /// Start a stopwatch at the current instant.
    pub fn start() -> Self {
        WallTimer {
            started: Instant::now(),
        }
    }

    /// Real seconds elapsed since [`WallTimer::start`].
    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

/// Virtual clock (nanoseconds).
#[derive(Debug, Default, Clone, Copy)]
pub struct SimClock {
    now_ns: f64,
}

impl SimClock {
    /// Clock at t=0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time (ns).
    #[inline]
    pub fn now_ns(&self) -> f64 {
        self.now_ns
    }

    /// Advance by a cost.
    #[inline]
    pub fn advance(&mut self, cost_ns: f64) {
        debug_assert!(cost_ns >= 0.0);
        self.now_ns += cost_ns;
    }

    /// Begin serving an event that arrived at `arrival_ns`: the clock
    /// jumps to the arrival if it is idle; returns the queueing latency
    /// `l_q` (0 when the operator was idle).
    #[inline]
    pub fn begin_service(&mut self, arrival_ns: f64) -> f64 {
        if self.now_ns < arrival_ns {
            self.now_ns = arrival_ns;
            0.0
        } else {
            self.now_ns - arrival_ns
        }
    }
}

impl Clock for SimClock {
    #[inline]
    fn now_ns(&self) -> f64 {
        SimClock::now_ns(self)
    }

    #[inline]
    fn advance(&mut self, cost_ns: f64) {
        SimClock::advance(self, cost_ns);
    }

    #[inline]
    fn begin_service(&mut self, arrival_ns: f64) -> f64 {
        SimClock::begin_service(self, arrival_ns)
    }

    fn wait_until(&mut self, t_ns: f64) {
        if self.now_ns < t_ns {
            self.now_ns = t_ns;
        }
    }

    fn idle(&mut self, ns: f64) {
        debug_assert!(ns >= 0.0);
        self.now_ns += ns;
    }
}

/// Monotonic wall clock with a virtual offset.
///
/// `now` is the real time elapsed since construction *plus* the
/// offset.  [`Clock::advance`] adds the modeled service cost to the
/// offset, so queueing dynamics follow the cost model exactly as they
/// do under [`SimClock`] while real time keeps flowing underneath
/// (external sources — sockets, tailed files — stay live).
/// [`Clock::wait_until`] fast-forwards the offset across scheduled idle
/// gaps instead of sleeping, which is what lets a wall-clock overload
/// experiment modeling seconds of load finish in milliseconds; only
/// [`Clock::idle`] (unscheduled external waits) really sleeps.
#[derive(Debug, Clone)]
pub struct WallClock {
    origin: Instant,
    offset_ns: f64,
}

impl WallClock {
    /// Clock anchored at the current instant with a zero offset.
    pub fn new() -> Self {
        WallClock {
            origin: Instant::now(),
            offset_ns: 0.0,
        }
    }

    /// Fast-forward the timeline by `ns` without sleeping (tests).
    pub fn fast_forward(&mut self, ns: f64) {
        debug_assert!(ns >= 0.0);
        self.offset_ns += ns;
    }

    /// The accumulated virtual offset over real elapsed time (ns).
    pub fn offset_ns(&self) -> f64 {
        self.offset_ns
    }

    /// Real (un-offset) nanoseconds elapsed since construction.
    pub fn real_elapsed_ns(&self) -> f64 {
        self.origin.elapsed().as_nanos() as f64
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    #[inline]
    fn now_ns(&self) -> f64 {
        self.real_elapsed_ns() + self.offset_ns
    }

    #[inline]
    fn advance(&mut self, cost_ns: f64) {
        debug_assert!(cost_ns >= 0.0);
        self.offset_ns += cost_ns;
    }

    #[inline]
    fn begin_service(&mut self, arrival_ns: f64) -> f64 {
        let now = self.now_ns();
        if now < arrival_ns {
            // service can't start before the event exists: fast-forward
            // to the arrival, exactly like the virtual clock's jump
            self.offset_ns += arrival_ns - now;
            0.0
        } else {
            now - arrival_ns
        }
    }

    fn wait_until(&mut self, t_ns: f64) {
        let now = self.now_ns();
        if now < t_ns {
            self.offset_ns += t_ns - now;
        }
    }

    fn idle(&mut self, ns: f64) {
        debug_assert!(ns >= 0.0);
        std::thread::sleep(std::time::Duration::from_nanos(ns as u64));
    }

    fn is_wall(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_operator_has_no_queueing() {
        let mut c = SimClock::new();
        assert_eq!(c.begin_service(100.0), 0.0);
        assert_eq!(c.now_ns(), 100.0);
    }

    #[test]
    fn busy_operator_queues() {
        let mut c = SimClock::new();
        c.begin_service(0.0);
        c.advance(500.0); // processing took 500ns
        let lq = c.begin_service(100.0); // event arrived at 100
        assert_eq!(lq, 400.0);
        assert_eq!(c.now_ns(), 500.0);
    }

    #[test]
    fn queueing_accumulates_under_overload() {
        // arrivals every 10ns, service 15ns: l_q grows linearly
        let mut c = SimClock::new();
        let mut last_lq = 0.0;
        for i in 0..100 {
            let lq = c.begin_service(i as f64 * 10.0);
            assert!(lq >= last_lq);
            last_lq = lq;
            c.advance(15.0);
        }
        assert!((last_lq - 99.0 * 5.0).abs() < 1e-9);
    }

    #[test]
    fn trait_dispatch_matches_inherent_simclock() {
        // the extraction contract: driving SimClock through the trait
        // object produces bit-identical time to the inherent calls
        let mut direct = SimClock::new();
        let mut boxed: Box<dyn Clock> = Box::new(SimClock::new());
        for i in 0..1_000u64 {
            let arrival = i as f64 * 13.7;
            let a = direct.begin_service(arrival);
            let b = boxed.begin_service(arrival);
            assert_eq!(a.to_bits(), b.to_bits());
            direct.advance(17.3);
            boxed.advance(17.3);
            assert_eq!(direct.now_ns().to_bits(), boxed.now_ns().to_bits());
        }
    }

    #[test]
    fn sim_wait_until_jumps_forward_only() {
        let mut c = SimClock::new();
        Clock::wait_until(&mut c, 500.0);
        assert_eq!(c.now_ns(), 500.0);
        Clock::wait_until(&mut c, 100.0); // past: no-op
        assert_eq!(c.now_ns(), 500.0);
        Clock::idle(&mut c, 50.0);
        assert_eq!(c.now_ns(), 550.0);
    }

    #[test]
    fn wall_clock_is_monotonic_and_fast_forwards() {
        let mut w = WallClock::new();
        assert!(w.is_wall());
        let t0 = w.now_ns();
        w.fast_forward(1e9); // jump a modeled second, no sleeping
        let t1 = w.now_ns();
        assert!(t1 - t0 >= 1e9, "offset must move time forward");
        w.advance(5e8); // modeled service occupies the timeline too
        assert!(w.now_ns() - t1 >= 5e8);
        assert!(w.offset_ns() >= 1.5e9);
        // real time underneath stays tiny compared to the offset
        assert!(w.real_elapsed_ns() < 1e9);
    }

    #[test]
    fn wall_begin_service_measures_queueing_against_the_timeline() {
        let mut w = WallClock::new();
        // a future arrival: service fast-forwards, no queueing
        let future = w.now_ns() + 1e6;
        assert_eq!(w.begin_service(future), 0.0);
        // modeled busy period makes the next event queue
        w.advance(2e6);
        let arrival = w.now_ns() - 1.5e6;
        assert!(w.begin_service(arrival) >= 1.5e6);
    }
}
