//! The operator's virtual clock.

/// Virtual clock (nanoseconds).
#[derive(Debug, Default, Clone, Copy)]
pub struct SimClock {
    now_ns: f64,
}

impl SimClock {
    /// Clock at t=0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time (ns).
    #[inline]
    pub fn now_ns(&self) -> f64 {
        self.now_ns
    }

    /// Advance by a cost.
    #[inline]
    pub fn advance(&mut self, cost_ns: f64) {
        debug_assert!(cost_ns >= 0.0);
        self.now_ns += cost_ns;
    }

    /// Begin serving an event that arrived at `arrival_ns`: the clock
    /// jumps to the arrival if it is idle; returns the queueing latency
    /// `l_q` (0 when the operator was idle).
    #[inline]
    pub fn begin_service(&mut self, arrival_ns: f64) -> f64 {
        if self.now_ns < arrival_ns {
            self.now_ns = arrival_ns;
            0.0
        } else {
            self.now_ns - arrival_ns
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_operator_has_no_queueing() {
        let mut c = SimClock::new();
        assert_eq!(c.begin_service(100.0), 0.0);
        assert_eq!(c.now_ns(), 100.0);
    }

    #[test]
    fn busy_operator_queues() {
        let mut c = SimClock::new();
        c.begin_service(0.0);
        c.advance(500.0); // processing took 500ns
        let lq = c.begin_service(100.0); // event arrived at 100
        assert_eq!(lq, 400.0);
        assert_eq!(c.now_ns(), 500.0);
    }

    #[test]
    fn queueing_accumulates_under_overload() {
        // arrivals every 10ns, service 15ns: l_q grows linearly
        let mut c = SimClock::new();
        let mut last_lq = 0.0;
        for i in 0..100 {
            let lq = c.begin_service(i as f64 * 10.0);
            assert!(lq >= last_lq);
            last_lq = lq;
            c.advance(15.0);
        }
        assert!((last_lq - 99.0 * 5.0).abs() < 1e-9);
    }
}
