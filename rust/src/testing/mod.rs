//! Minimal property-testing support (offline stand-in for `proptest`,
//! which is not in the vendored crate set — see DESIGN.md §3).
//!
//! Provides seeded case generation and a `forall` runner that reports
//! the failing seed + case index so failures are reproducible:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this offline image)
//! use pspice::testing::{forall, Gen};
//! forall(100, 42, |g| {
//!     let x = g.int(0, 1000);
//!     assert!(x >= 0);
//! });
//! ```

use crate::util::Rng;

/// Case generator handed to property bodies.
pub struct Gen {
    rng: Rng,
    /// which case is running (for diagnostics)
    pub case: usize,
}

impl Gen {
    /// Integer in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    /// Usize in `[lo, hi]` inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Bernoulli.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    /// Vector of generated values.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// A random row-stochastic matrix with absorbing final state
    /// (the shape every Markov property in this crate quantifies over).
    pub fn stochastic_matrix(&mut self, m: usize) -> crate::linalg::Mat {
        let mut t = crate::linalg::Mat::zeros(m, m);
        for i in 0..m - 1 {
            let mut row: Vec<f64> = (0..m).map(|_| self.f64(1e-3, 1.0)).collect();
            let s: f64 = row.iter().sum();
            for v in &mut row {
                *v /= s;
            }
            for (j, v) in row.iter().enumerate() {
                t[(i, j)] = *v;
            }
        }
        t[(m - 1, m - 1)] = 1.0;
        t
    }

    /// Fork an independent RNG (for building seeded components).
    pub fn rng(&mut self) -> Rng {
        self.rng.fork()
    }
}

/// Run `cases` property cases with a base seed.  Panics (with seed and
/// case number) on the first failing case.
pub fn forall(cases: usize, seed: u64, mut body: impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let mut g = Gen {
            rng: Rng::seeded(seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            case,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut g)
        }));
        if let Err(e) = result {
            eprintln!("property failed: seed={seed} case={case}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(25, 1, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn gen_is_deterministic_per_seed_and_case() {
        let mut first = Vec::new();
        forall(5, 7, |g| first.push(g.int(0, 1_000_000)));
        let mut second = Vec::new();
        forall(5, 7, |g| second.push(g.int(0, 1_000_000)));
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        forall(10, 3, |g| {
            let x = g.int(0, 100);
            assert!(x < 95, "x={x}");
        });
    }

    #[test]
    fn stochastic_matrix_is_stochastic() {
        forall(20, 11, |g| {
            let m = g.usize(2, 12);
            let t = g.stochastic_matrix(m);
            assert!(t.is_row_stochastic(1e-9));
        });
    }
}
