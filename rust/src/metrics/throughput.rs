//! Wall-clock throughput accounting: events per second of real time
//! (as opposed to the virtual-time latency tracking in [`super::latency`]).
//! Used by the sharded-runtime benches and the experiment harness to
//! report how fast the measurement phase actually ran.

/// Accumulated (events, seconds) with derived rates.
#[derive(Debug, Default, Clone, Copy)]
pub struct Throughput {
    events: u64,
    secs: f64,
}

impl Throughput {
    /// Empty meter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a measured interval.
    pub fn record(&mut self, events: u64, secs: f64) {
        debug_assert!(secs >= 0.0);
        self.events += events;
        self.secs += secs;
    }

    /// Total events recorded.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Total seconds recorded.
    pub fn secs(&self) -> f64 {
        self.secs
    }

    /// Events per wall-clock second (0 before anything is recorded).
    pub fn events_per_sec(&self) -> f64 {
        if self.secs > 0.0 {
            self.events as f64 / self.secs
        } else {
            0.0
        }
    }

    /// Speedup of this meter over a baseline meter.
    pub fn speedup_over(&self, base: &Throughput) -> f64 {
        let b = base.events_per_sec();
        if b > 0.0 {
            self.events_per_sec() / b
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_accumulate() {
        let mut t = Throughput::new();
        assert_eq!(t.events_per_sec(), 0.0);
        t.record(1_000, 0.5);
        t.record(1_000, 0.5);
        assert_eq!(t.events(), 2_000);
        assert!((t.events_per_sec() - 2_000.0).abs() < 1e-9);
    }

    #[test]
    fn speedup_is_relative() {
        let mut a = Throughput::new();
        a.record(4_000, 1.0);
        let mut b = Throughput::new();
        b.record(1_000, 1.0);
        assert!((a.speedup_over(&b) - 4.0).abs() < 1e-9);
        assert_eq!(a.speedup_over(&Throughput::new()), 0.0);
    }
}
