//! Event-latency tracking (paper Fig. 7): per-event `l_e` samples in
//! virtual time, bound-violation accounting, and a down-sampled trace
//! for plotting.

use crate::util::OnlineStats;

/// Tracks event latencies against a bound.
#[derive(Debug, Clone)]
pub struct LatencyTracker {
    /// the latency bound LB (ns)
    pub lb_ns: f64,
    /// all-sample statistics
    pub stats: OnlineStats,
    /// number of samples above LB
    pub violations: u64,
    /// down-sampled (virtual time ns, latency ns) trace
    pub trace: Vec<(f64, f64)>,
    /// keep every k-th sample in the trace
    stride: u64,
    seen: u64,
    /// bounded quantile reservoir: every `q_stride`-th sample, thinned
    /// every-other when full (same scheme as the detector's sample
    /// caps), so tail quantiles stay available at O(1) memory
    q_samples: Vec<f64>,
    q_stride: u64,
}

/// Quantile reservoir cap: past this many kept samples, keep every
/// other one and double the keep stride.
const QUANTILE_CAP: usize = 8_192;

impl LatencyTracker {
    /// Tracker with a plotting stride (keep every `stride`-th sample).
    pub fn new(lb_ns: f64, stride: u64) -> Self {
        LatencyTracker {
            lb_ns,
            stats: OnlineStats::new(),
            violations: 0,
            trace: Vec::new(),
            stride: stride.max(1),
            seen: 0,
            q_samples: Vec::new(),
            q_stride: 1,
        }
    }

    /// Record one event latency at virtual time `now_ns`.
    #[inline]
    pub fn record(&mut self, now_ns: f64, l_e_ns: f64) {
        self.stats.push(l_e_ns);
        if l_e_ns > self.lb_ns {
            self.violations += 1;
        }
        if self.seen % self.stride == 0 {
            self.trace.push((now_ns, l_e_ns));
        }
        if self.seen % self.q_stride == 0 {
            self.q_samples.push(l_e_ns);
            if self.q_samples.len() >= QUANTILE_CAP {
                let mut keep = false;
                self.q_samples.retain(|_| {
                    keep = !keep;
                    keep
                });
                self.q_stride *= 2;
            }
        }
        self.seen += 1;
    }

    /// Fraction of events that violated the bound.
    pub fn violation_rate(&self) -> f64 {
        if self.stats.count() == 0 {
            0.0
        } else {
            self.violations as f64 / self.stats.count() as f64
        }
    }

    /// Latency quantile `q` in [0, 1] from the bounded reservoir
    /// (nearest-rank on the kept samples; 0.0 with no samples).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.q_samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.q_samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let rank = (q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    /// The p95 latency (ns) — the real-time SLO gate.
    pub fn p95_ns(&self) -> f64 {
        self.quantile(0.95)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_violations() {
        let mut t = LatencyTracker::new(100.0, 1);
        t.record(0.0, 50.0);
        t.record(1.0, 150.0);
        t.record(2.0, 99.0);
        assert_eq!(t.violations, 1);
        assert!((t.violation_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(t.trace.len(), 3);
    }

    #[test]
    fn stride_downsamples_trace() {
        let mut t = LatencyTracker::new(100.0, 10);
        for i in 0..100 {
            t.record(i as f64, 1.0);
        }
        assert_eq!(t.trace.len(), 10);
        assert_eq!(t.stats.count(), 100);
    }

    #[test]
    fn quantiles_track_the_distribution() {
        let mut t = LatencyTracker::new(1e9, 1);
        // 1..=1000 in scrambled order
        for i in 0..1000u64 {
            let v = ((i * 617) % 1000 + 1) as f64;
            t.record(i as f64, v);
        }
        assert!((t.quantile(0.0) - 1.0).abs() < 1e-9);
        assert!((t.quantile(1.0) - 1000.0).abs() < 1e-9);
        let p50 = t.quantile(0.5);
        assert!((450.0..=550.0).contains(&p50), "p50={p50}");
        let p95 = t.p95_ns();
        assert!((930.0..=970.0).contains(&p95), "p95={p95}");
        assert_eq!(LatencyTracker::new(1.0, 1).quantile(0.5), 0.0);
    }

    #[test]
    fn quantile_reservoir_stays_bounded() {
        let mut t = LatencyTracker::new(1e9, 1);
        for i in 0..100_000u64 {
            t.record(i as f64, (i % 100) as f64);
        }
        assert!(t.q_samples.len() < super::QUANTILE_CAP);
        assert_eq!(t.stats.count(), 100_000);
        // the thinned reservoir still sees the whole range
        let p95 = t.p95_ns();
        assert!((90.0..=99.0).contains(&p95), "p95={p95}");
    }
}
