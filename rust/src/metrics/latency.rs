//! Event-latency tracking (paper Fig. 7): per-event `l_e` samples in
//! virtual time, bound-violation accounting, and a down-sampled trace
//! for plotting.

use crate::util::OnlineStats;

/// Tracks event latencies against a bound.
#[derive(Debug, Clone)]
pub struct LatencyTracker {
    /// the latency bound LB (ns)
    pub lb_ns: f64,
    /// all-sample statistics
    pub stats: OnlineStats,
    /// number of samples above LB
    pub violations: u64,
    /// down-sampled (virtual time ns, latency ns) trace
    pub trace: Vec<(f64, f64)>,
    /// keep every k-th sample in the trace
    stride: u64,
    seen: u64,
}

impl LatencyTracker {
    /// Tracker with a plotting stride (keep every `stride`-th sample).
    pub fn new(lb_ns: f64, stride: u64) -> Self {
        LatencyTracker {
            lb_ns,
            stats: OnlineStats::new(),
            violations: 0,
            trace: Vec::new(),
            stride: stride.max(1),
            seen: 0,
        }
    }

    /// Record one event latency at virtual time `now_ns`.
    #[inline]
    pub fn record(&mut self, now_ns: f64, l_e_ns: f64) {
        self.stats.push(l_e_ns);
        if l_e_ns > self.lb_ns {
            self.violations += 1;
        }
        if self.seen % self.stride == 0 {
            self.trace.push((now_ns, l_e_ns));
        }
        self.seen += 1;
    }

    /// Fraction of events that violated the bound.
    pub fn violation_rate(&self) -> f64 {
        if self.stats.count() == 0 {
            0.0
        } else {
            self.violations as f64 / self.stats.count() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_violations() {
        let mut t = LatencyTracker::new(100.0, 1);
        t.record(0.0, 50.0);
        t.record(1.0, 150.0);
        t.record(2.0, 99.0);
        assert_eq!(t.violations, 1);
        assert!((t.violation_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(t.trace.len(), 3);
    }

    #[test]
    fn stride_downsamples_trace() {
        let mut t = LatencyTracker::new(100.0, 10);
        for i in 0..100 {
            t.record(i as f64, 1.0);
        }
        assert_eq!(t.trace.len(), 10);
        assert_eq!(t.stats.count(), 100);
    }
}
