//! QoR accounting (paper §II-B): weighted false negatives against the
//! ground-truth run, `FN_Q = Σ w_q · FN_q`, reported as the percentage
//! of ground-truth complex events missed.  Also counts false positives
//! (which must be zero for the white-box shedders).

use std::collections::BTreeSet;

use crate::operator::ComplexEvent;

/// Shedding-invariant identity of a complex event: the completing
/// event's sequence number is excluded (different shedding decisions
/// may complete the same logical match on a different event).  Ordered
/// so the truth/detected sets iterate deterministically (the audit's
/// no-hash-iteration rule for result-affecting modules).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CeKey {
    /// query index
    pub query: usize,
    /// window identity
    pub window_open_seq: u64,
    /// bound correlation keys
    pub key_bits: u64,
}

impl From<&ComplexEvent> for CeKey {
    fn from(ce: &ComplexEvent) -> Self {
        CeKey {
            query: ce.query,
            window_open_seq: ce.window_open_seq,
            key_bits: ce.key_bits,
        }
    }
}

/// Ground-truth vs. detected comparison.
#[derive(Debug, Clone)]
pub struct QorAccounting {
    /// per-query weights `w_q`
    pub weights: Vec<f64>,
    /// ground-truth complex events
    pub truth: BTreeSet<CeKey>,
    /// detected complex events
    pub detected: BTreeSet<CeKey>,
    /// only count events whose window opened at/after this seq
    /// (excludes the calibration warm-up region)
    pub from_seq: u64,
}

impl QorAccounting {
    /// Accounting over queries with the given weights.
    pub fn new(weights: Vec<f64>, from_seq: u64) -> Self {
        QorAccounting {
            weights,
            truth: BTreeSet::new(),
            detected: BTreeSet::new(),
            from_seq,
        }
    }

    fn in_scope(&self, k: &CeKey) -> bool {
        k.window_open_seq >= self.from_seq
    }

    /// Add a ground-truth complex event.
    pub fn add_truth(&mut self, ce: &ComplexEvent) {
        let k = CeKey::from(ce);
        if self.in_scope(&k) {
            self.truth.insert(k);
        }
    }

    /// Add a detected complex event.
    pub fn add_detected(&mut self, ce: &ComplexEvent) {
        let k = CeKey::from(ce);
        if self.in_scope(&k) {
            self.detected.insert(k);
        }
    }

    /// Per-query false-negative counts.
    pub fn fn_by_query(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.weights.len()];
        for k in &self.truth {
            if !self.detected.contains(k) {
                out[k.query] += 1;
            }
        }
        out
    }

    /// Per-query ground-truth counts.
    pub fn truth_by_query(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.weights.len()];
        for k in &self.truth {
            out[k.query] += 1;
        }
        out
    }

    /// Weighted false-negative percentage:
    /// `100 · Σ w_q FN_q / Σ w_q GT_q`.
    pub fn fn_percent(&self) -> f64 {
        let fns = self.fn_by_query();
        let gts = self.truth_by_query();
        let num: f64 = fns
            .iter()
            .zip(&self.weights)
            .map(|(&f, &w)| w * f as f64)
            .sum();
        let den: f64 = gts
            .iter()
            .zip(&self.weights)
            .map(|(&g, &w)| w * g as f64)
            .sum();
        if den == 0.0 {
            0.0
        } else {
            100.0 * num / den
        }
    }

    /// Detected events not present in the ground truth (must be empty
    /// for PM shedding).
    pub fn false_positives(&self) -> usize {
        self.detected.difference(&self.truth).count()
    }

    /// Match probability of the ground truth run: completed PMs over
    /// all PMs (computed by the harness from operator counters; stored
    /// here for reports).
    pub fn truth_total(&self) -> usize {
        self.truth.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ce(query: usize, w: u64, k: u64) -> ComplexEvent {
        ComplexEvent {
            query,
            window_open_seq: w,
            key_bits: k,
            completed_seq: w + 100,
        }
    }

    #[test]
    fn fn_percent_counts_misses() {
        let mut q = QorAccounting::new(vec![1.0], 0);
        for i in 0..10 {
            q.add_truth(&ce(0, i, 0));
        }
        for i in 0..7 {
            q.add_detected(&ce(0, i, 0));
        }
        assert!((q.fn_percent() - 30.0).abs() < 1e-9);
        assert_eq!(q.false_positives(), 0);
    }

    #[test]
    fn weights_bias_fn_percent() {
        let mut q = QorAccounting::new(vec![1.0, 3.0], 0);
        q.add_truth(&ce(0, 1, 0));
        q.add_truth(&ce(1, 2, 0));
        // miss only the heavy query
        q.add_detected(&ce(0, 1, 0));
        // FN = (0·1 + 1·3) / (1 + 3) = 75%
        assert!((q.fn_percent() - 75.0).abs() < 1e-9);
    }

    #[test]
    fn scope_excludes_warmup() {
        let mut q = QorAccounting::new(vec![1.0], 1000);
        q.add_truth(&ce(0, 500, 0)); // warm-up: ignored
        q.add_truth(&ce(0, 1500, 0));
        assert_eq!(q.truth_total(), 1);
    }

    #[test]
    fn completing_seq_does_not_matter() {
        let mut q = QorAccounting::new(vec![1.0], 0);
        q.add_truth(&ComplexEvent {
            query: 0,
            window_open_seq: 5,
            key_bits: 9,
            completed_seq: 50,
        });
        q.add_detected(&ComplexEvent {
            query: 0,
            window_open_seq: 5,
            key_bits: 9,
            completed_seq: 80, // later completion, same logical event
        });
        assert_eq!(q.fn_percent(), 0.0);
        assert_eq!(q.false_positives(), 0);
    }
}
