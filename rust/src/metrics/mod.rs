//! Measurement: event latency, QoR (false negatives/positives against
//! ground truth), and throughput accounting.

pub mod latency;
pub mod qor;
pub mod throughput;

pub use latency::LatencyTracker;
pub use qor::{CeKey, QorAccounting};
pub use throughput::Throughput;
