//! Fig. 9b bench — wall-clock model-build time vs window size, for both
//! engines (AOT/PJRT artifact vs rust fallback).  The paper reports
//! 1 s → 2.4 s over ws = 6K → 32K on 2010 hardware; the *shape*
//! (monotone growth with ws = more value-iteration steps) is the claim.

mod common;

use common::bench;
use pspice::datasets::StockGen;
use pspice::events::EventStream;
use pspice::model::{ModelBuilder, ModelConfig};
use pspice::operator::Operator;
use pspice::query::builtin::q1;
use pspice::runtime::{ArtifactManifest, FallbackEngine, PjrtEngine};

fn trained_op(ws: u64) -> Operator {
    let mut op = Operator::new(q1(ws).queries);
    let mut g = StockGen::with_seed(3);
    // enough events to populate transitions without over-long runs
    for _ in 0..30_000 {
        op.process_event(&g.next_event().unwrap());
    }
    op
}

fn main() {
    println!("== model_build (Fig. 9b wall-clock) ==");
    let have_pjrt = PjrtEngine::load(&ArtifactManifest::default_dir()).is_ok();
    for &ws in &[6_000u64, 10_000, 16_000, 18_000, 24_000, 32_000] {
        let op = trained_op(ws);
        let cfg = ModelConfig {
            eta: 1,
            max_bins: 512,
            use_tau: true,
        };
        if have_pjrt {
            let engine = PjrtEngine::load(&ArtifactManifest::default_dir()).unwrap();
            let mut mb = ModelBuilder::new(cfg.clone(), Box::new(engine));
            mb.build(&op).unwrap(); // compile once outside the timing
            bench(&format!("model_build.pjrt(ws={ws})"), 1, 10, 0, || {
                mb.build(&op).unwrap();
            });
        }
        let mut mb = ModelBuilder::new(cfg, Box::new(FallbackEngine));
        bench(&format!("model_build.fallback(ws={ws})"), 1, 10, 0, || {
            mb.build(&op).unwrap();
        });
    }
}
