//! Fig. 9b bench — wall-clock model-build time vs window size, for both
//! engines (AOT/PJRT artifact vs rust fallback).  The paper reports
//! 1 s → 2.4 s over ws = 6K → 32K on 2010 hardware; the *shape*
//! (monotone growth with ws = more value-iteration steps) is the claim.

mod common;

use common::bench;
use pspice::datasets::StockGen;
use pspice::events::EventStream;
use pspice::model::{ModelBuilder, ModelConfig};
use pspice::operator::Operator;
use pspice::query::builtin::q1;
use pspice::runtime::FallbackEngine;

fn trained_op(ws: u64) -> Operator {
    let mut op = Operator::new(q1(ws).queries);
    let mut g = StockGen::with_seed(3);
    // enough events to populate transitions without over-long runs
    for _ in 0..30_000 {
        op.process_event(&g.next_event().unwrap());
    }
    op
}

/// Bench the AOT/PJRT engine when the crate is built with `--features
/// xla` and artifacts exist; a no-op otherwise.
#[cfg(feature = "xla")]
fn bench_pjrt(op: &Operator, cfg: &ModelConfig, ws: u64) {
    use pspice::runtime::{ArtifactManifest, PjrtEngine};
    let Ok(engine) = PjrtEngine::load(&ArtifactManifest::default_dir()) else {
        return;
    };
    let mut mb = ModelBuilder::new(cfg.clone(), Box::new(engine));
    mb.build(op).unwrap(); // compile once outside the timing
    bench(&format!("model_build.pjrt(ws={ws})"), 1, 10, 0, || {
        mb.build(op).unwrap();
    });
}

#[cfg(not(feature = "xla"))]
fn bench_pjrt(_op: &Operator, _cfg: &ModelConfig, _ws: u64) {}

fn main() {
    println!("== model_build (Fig. 9b wall-clock) ==");
    for &ws in &[6_000u64, 10_000, 16_000, 18_000, 24_000, 32_000] {
        let op = trained_op(ws);
        let cfg = ModelConfig {
            eta: 1,
            max_bins: 512,
            use_tau: true,
        };
        bench_pjrt(&op, &cfg, ws);
        let mut mb = ModelBuilder::new(cfg, Box::new(FallbackEngine));
        bench(&format!("model_build.fallback(ws={ws})"), 1, 10, 0, || {
            mb.build(&op).unwrap();
        });
    }
}
