//! Fig. 9a bench — wall-clock cost of the shedding primitives at
//! realistic PM populations, now centered on the PR-3 acceptance
//! comparison: the **cell-based** shed decision (enumerate + sort
//! O(cells) summaries off the per-window state counts) versus the
//! **legacy per-PM** decision (materialize a `PmRef` + utility pair per
//! PM, `select_nth_unstable`, build the victim id hash-set), which is
//! what `shed_lowest` did before the cell index existed.
//!
//! Prints an explicit PASS/FAIL line for the ≥2× shed-decision speedup
//! target at the largest population and records every measurement in
//! `BENCH_pr3.json` (see `common::emit_json`).  `-- --smoke` runs a
//! tiny configuration for CI.

mod common;


use common::{bench, black_box, emit_json, smoke_mode, BenchResult};
use pspice::datasets::BusGen;
use pspice::events::EventStream;
use pspice::model::{ModelBuilder, ModelConfig};
use pspice::operator::{cell_cmp, CellTake, Operator, PmRef, ShedCell};
use pspice::query::builtin::q4;
use pspice::runtime::FallbackEngine;
use pspice::util::Rng;

fn operator_with_pms(target_pms: usize) -> Operator {
    // big windows + small slide grow the PM population; the event cap
    // bounds setup time (q4's PM population saturates at
    // #windows × (#stops + 1), so very large targets are best-effort)
    let mut op = Operator::new(q4(8, 60_000, 40).queries);
    let mut g = BusGen::with_seed(1);
    let mut budget = 3_000_000u64;
    while op.pm_count() < target_pms && budget > 0 {
        op.process_event(&g.next_event().unwrap());
        budget -= 1;
    }
    op
}

fn main() {
    println!("== shed_overhead (Fig. 9a wall-clock) ==");
    let smoke = smoke_mode();
    let sizes: &[usize] = if smoke {
        &[2_000]
    } else {
        &[1_000, 10_000, 50_000]
    };
    let reps = if smoke { 5 } else { 20 };
    let mut results: Vec<BenchResult> = Vec::new();
    let mut last_speedup = 0.0f64;
    let mut last_n = 0usize;
    for &target in sizes {
        let op = operator_with_pms(target);
        let n = op.pm_count(); // actual population (saturation-aware)
        let mut mb = ModelBuilder::new(
            ModelConfig {
                eta: 1,
                max_bins: 128,
                use_tau: true,
            },
            Box::new(FallbackEngine),
        );
        let tables = mb.build(&op).unwrap();
        let rho = n / 10;

        let mut tabled = op.clone();
        tabled.install_tables(&tables);

        // --- the acceptance pair: decision cost, cell vs legacy ------

        // cell-based decision: O(cells) enumeration off the window
        // state counts + sort + take construction + the per-window
        // regroup sort (exactly what `shed_lowest` does before the
        // in-place drop)
        let mut cells: Vec<ShedCell> = Vec::new();
        let mut takes: Vec<CellTake> = Vec::new();
        let cell_decide = bench(
            &format!("cell.decide(n={n}, rho={rho})"),
            3,
            reps,
            n as u64,
            || {
                tabled.cell_refs(&mut cells);
                cells.sort_unstable_by(cell_cmp);
                takes.clear();
                let mut left = rho;
                for c in &cells {
                    if left == 0 {
                        break;
                    }
                    let take = (c.count as usize).min(left) as u32;
                    left -= take as usize;
                    takes.push(CellTake {
                        query: c.query,
                        open_seq: c.open_seq,
                        state: c.state,
                        take,
                    });
                }
                takes.sort_unstable_by_key(|t| (t.query, t.open_seq, t.state));
                black_box(takes.len());
            },
        );
        println!("  ({} cells for {} PMs)", cells.len(), n);

        // legacy per-PM decision: what shed_lowest cost before PR 3
        let mut refs: Vec<PmRef> = Vec::new();
        let mut keyed: Vec<(f64, u64)> = Vec::new();
        let legacy_decide = bench(
            &format!("legacy.decide(n={n}, rho={rho})"),
            3,
            reps,
            n as u64,
            || {
                op.pm_refs(&mut refs);
                keyed.clear();
                keyed.reserve(refs.len());
                for r in &refs {
                    keyed.push((tables[r.query].lookup(r.state, r.remaining), r.pm_id));
                }
                if rho > 0 && rho < keyed.len() {
                    keyed.select_nth_unstable_by(rho - 1, |a, b| a.0.total_cmp(&b.0));
                }
                let mut ids: Vec<u64> = keyed[..rho].iter().map(|&(_, id)| id).collect();
                ids.sort_unstable();
                black_box(ids.len());
            },
        );

        last_speedup = legacy_decide.mean_s / cell_decide.mean_s.max(1e-12);
        last_n = n;
        results.push(BenchResult {
            name: format!("derived.decide_speedup(n={n})"),
            mean_s: last_speedup,
            stddev_s: 0.0,
            items: 0,
        });

        // --- full in-place passes and baselines ----------------------

        // pSPICE drop end to end: decision + in-place cell drop
        results.push(bench(
            &format!("operator.shed_lowest(n={n}, rho={rho})"),
            3,
            reps,
            n as u64,
            || {
                let mut op2 = tabled.clone();
                black_box(op2.shed_lowest(rho));
            },
        ));

        // legacy end to end: per-PM decision + id-set retain over
        // every window
        let victims: Vec<u64> = {
            op.pm_refs(&mut refs);
            let mut v: Vec<u64> = refs.iter().take(rho).map(|r| r.pm_id).collect();
            v.sort_unstable();
            v
        };
        results.push(bench(
            &format!("legacy.drop_pms(n={n}, rho={rho})"),
            3,
            reps,
            n as u64,
            || {
                let mut op2 = op.clone();
                black_box(op2.drop_pms(&victims));
            },
        ));

        // PM-BL random drop (scratch-buffer path)
        results.push(bench(
            &format!("pm_bl.drop_random(n={n}, rho={rho})"),
            3,
            reps,
            n as u64,
            || {
                let mut op2 = op.clone();
                let mut rng = Rng::seeded(7);
                black_box(op2.drop_random(rho, &mut rng));
            },
        ));

        // utility lookup alone (the O(1) claim), per cell vs per PM
        results.push(bench(
            &format!("pspice.utility_lookup_per_pm(n={n})"),
            3,
            reps,
            n as u64,
            || {
                let mut acc = 0.0;
                for r in &refs {
                    acc += tables[r.query].lookup(r.state, r.remaining);
                }
                black_box(acc);
            },
        ));

        results.push(cell_decide);
        results.push(legacy_decide);
        println!();
    }

    let pass = last_speedup >= 2.0;
    println!(
        "  target >=2x shed-decision speedup at n={last_n}: {}{} ({last_speedup:.2}x)",
        if pass { "PASS" } else { "FAIL" },
        if smoke { " [informational at smoke scale]" } else { "" }
    );
    if let Err(e) = emit_json("shed_overhead", &results, "BENCH_pr3.json") {
        eprintln!("warning: could not write bench json: {e}");
    }
    // enforce the acceptance gate at the real (>=50k PM) configuration;
    // smoke scale is too small and noisy to gate CI on
    if !smoke && !pass {
        std::process::exit(1);
    }
}
