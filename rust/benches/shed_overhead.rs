//! Fig. 9a bench — wall-clock cost of the shedding primitives at
//! realistic PM populations, for all three strategies, plus the
//! sort-vs-select ablation the paper's complexity analysis motivates
//! (paper budgets O(n log n); our selection is O(n)).

mod common;

use std::collections::HashSet;

use common::{bench, black_box};
use pspice::datasets::BusGen;
use pspice::events::EventStream;
use pspice::model::{ModelBuilder, ModelConfig};
use pspice::operator::Operator;
use pspice::query::builtin::q4;
use pspice::runtime::FallbackEngine;
use pspice::util::Rng;

fn operator_with_pms(target_pms: usize) -> Operator {
    // big windows + small slide grow the PM population; the event cap
    // bounds setup time (q4's PM population saturates at
    // #windows × (#stops + 1), so very large targets are best-effort)
    let mut op = Operator::new(q4(8, 40_000, 50).queries);
    let mut g = BusGen::with_seed(1);
    let mut budget = 2_000_000u64;
    while op.pm_count() < target_pms && budget > 0 {
        op.process_event(&g.next_event().unwrap());
        budget -= 1;
    }
    op
}

fn main() {
    println!("== shed_overhead (Fig. 9a wall-clock) ==");
    for &n in &[1_000usize, 10_000, 40_000] {
        let op = operator_with_pms(n);
        let n = op.pm_count(); // actual population (saturation-aware)
        let mut mb = ModelBuilder::new(
            ModelConfig {
                eta: 1,
                max_bins: 128,
                use_tau: true,
            },
            Box::new(FallbackEngine),
        );
        let tables = mb.build(&op).unwrap();
        let rho = n / 10;

        // pSPICE drop: enumerate + utility + select + remove
        bench(
            &format!("operator.shed_lowest(n={n}, rho={rho})"),
            3,
            20,
            n as u64,
            || {
                let mut op2 = op.clone();
                op2.install_tables(&tables);
                black_box(op2.shed_lowest(rho));
            },
        );

        // PM-BL random drop
        bench(
            &format!("pm_bl.drop_random(n={n}, rho={rho})"),
            3,
            20,
            n as u64,
            || {
                let mut op2 = op.clone();
                let mut rng = Rng::seeded(7);
                black_box(op2.drop_random(rho, &mut rng));
            },
        );

        // ablation: full sort (the paper's O(n log n)) vs our selection
        let mut refs = Vec::new();
        op.pm_refs(&mut refs);
        let utils: Vec<(f64, u64)> = refs
            .iter()
            .map(|r| (tables[r.query].lookup(r.state, r.remaining), r.pm_id))
            .collect();
        bench(&format!("ablation.full_sort(n={n})"), 3, 20, n as u64, || {
            let mut v = utils.clone();
            v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            black_box(&v);
        });
        bench(
            &format!("ablation.select_nth(n={n}, rho={rho})"),
            3,
            20,
            n as u64,
            || {
                let mut v = utils.clone();
                if rho < v.len() {
                    v.select_nth_unstable_by(rho - 1, |a, b| {
                        a.0.partial_cmp(&b.0).unwrap()
                    });
                }
                black_box(&v);
            },
        );

        // utility lookup alone (the O(1) claim)
        bench(
            &format!("pspice.utility_lookup(n={n})"),
            3,
            50,
            n as u64,
            || {
                let mut acc = 0.0;
                for r in &refs {
                    acc += tables[r.query].lookup(r.state, r.remaining);
                }
                black_box(acc);
            },
        );

        // drop by id set (operator-side removal)
        let victims: HashSet<u64> = refs.iter().take(rho).map(|r| r.pm_id).collect();
        bench(
            &format!("operator.drop_pms(n={n}, rho={rho})"),
            3,
            20,
            n as u64,
            || {
                let mut op2 = op.clone();
                black_box(op2.drop_pms(&victims));
            },
        );
        println!();
    }
}
