//! Operator hot-path benches: wall-clock events/s of the match loop at
//! different PM populations (the L3 request path the paper's `f(n_pm)`
//! regression models), plus the per-component costs.  Records every
//! measurement into `BENCH_pr3.json`; `-- --smoke` runs a tiny
//! configuration for CI's perf-smoke job.

mod common;

use common::{bench, black_box, emit_json, smoke_mode, BenchResult};
use pspice::datasets::{BusGen, StockGen};
use pspice::events::EventStream;
use pspice::operator::Operator;
use pspice::query::builtin::{q1, q4};

fn main() {
    println!("== operator_throughput ==");
    let smoke = smoke_mode();
    let windows: &[u64] = if smoke { &[1_000] } else { &[1_000, 5_000, 10_000] };
    let (q4_warm, batch_len, reps) = if smoke {
        (10_000usize, 2_000usize, 5usize)
    } else {
        (40_000, 5_000, 10)
    };
    let mut results: Vec<BenchResult> = Vec::new();

    // q1: many windows, 11-state sequences over quotes
    for &ws in windows {
        let mut op = Operator::new(q1(ws).queries);
        let mut g = StockGen::with_seed(1);
        for _ in 0..3 * ws {
            op.process_event(&g.next_event().unwrap());
        }
        let batch: Vec<_> = g.take_events(batch_len);
        let pms = op.pm_count();
        results.push(bench(
            &format!("q1.process_event(ws={ws}, pms={pms})"),
            1,
            reps,
            batch.len() as u64,
            || {
                let mut op2 = op.clone();
                let mut checks = 0u64;
                for e in &batch {
                    checks += op2.process_event(e).checks;
                }
                black_box(checks);
            },
        ));
    }

    // q4: fewer windows, any-operator with key correlation
    let mut op = Operator::new(q4(6, 20_000, 100).queries);
    let mut g = BusGen::with_seed(2);
    for _ in 0..q4_warm {
        op.process_event(&g.next_event().unwrap());
    }
    let batch: Vec<_> = g.take_events(batch_len);
    let pms = op.pm_count();
    results.push(bench(
        &format!("q4.process_event(pms={pms})"),
        1,
        reps,
        batch.len() as u64,
        || {
            let mut op2 = op.clone();
            for e in &batch {
                black_box(op2.process_event(e).checks);
            }
        },
    ));

    // observation capture on/off delta
    let mut op_obs = op.clone();
    op_obs.obs.enabled = false;
    results.push(bench(
        &format!("q4.process_event(no-obs, pms={pms})"),
        1,
        reps,
        batch.len() as u64,
        || {
            let mut op2 = op_obs.clone();
            for e in &batch {
                black_box(op2.process_event(e).checks);
            }
        },
    ));

    // bookkeeping-only path (E-BL dropped events) — exercises the
    // allocation-free no-expiry fast path of QueryWindows::expire
    results.push(bench(
        &format!("q4.process_bookkeeping(pms={pms})"),
        1,
        reps,
        batch.len() as u64,
        || {
            let mut op2 = op.clone();
            for e in &batch {
                black_box(op2.process_bookkeeping(e).opened);
            }
        },
    ));

    // dataset generation itself
    let gen_n: u64 = if smoke { 20_000 } else { 100_000 };
    results.push(bench("stockgen.next_event", 1, reps, gen_n, || {
        let mut g = StockGen::with_seed(9);
        for _ in 0..gen_n {
            black_box(g.next_event());
        }
    }));

    if let Err(e) = emit_json("operator_throughput", &results, "BENCH_pr3.json") {
        eprintln!("warning: could not write bench json: {e}");
    }
}
