//! Operator hot-path benches: wall-clock events/s of the match loop at
//! different PM populations (the L3 request path the paper's `f(n_pm)`
//! regression models), plus the per-component costs.

mod common;

use common::{bench, black_box};
use pspice::datasets::{BusGen, StockGen};
use pspice::events::EventStream;
use pspice::operator::Operator;
use pspice::query::builtin::{q1, q4};

fn main() {
    println!("== operator_throughput ==");

    // q1: many windows, 11-state sequences over quotes
    for &ws in &[1_000u64, 5_000, 10_000] {
        let mut op = Operator::new(q1(ws).queries);
        let mut g = StockGen::with_seed(1);
        for _ in 0..3 * ws {
            op.process_event(&g.next_event().unwrap());
        }
        let batch: Vec<_> = g.take_events(5_000);
        let pms = op.pm_count();
        bench(
            &format!("q1.process_event(ws={ws}, pms={pms})"),
            1,
            10,
            batch.len() as u64,
            || {
                let mut op2 = op.clone();
                let mut checks = 0u64;
                for e in &batch {
                    checks += op2.process_event(e).checks;
                }
                black_box(checks);
            },
        );
    }

    // q4: fewer windows, any-operator with key correlation
    let mut op = Operator::new(q4(6, 20_000, 100).queries);
    let mut g = BusGen::with_seed(2);
    for _ in 0..40_000 {
        op.process_event(&g.next_event().unwrap());
    }
    let batch: Vec<_> = g.take_events(5_000);
    let pms = op.pm_count();
    bench(
        &format!("q4.process_event(pms={pms})"),
        1,
        10,
        batch.len() as u64,
        || {
            let mut op2 = op.clone();
            for e in &batch {
                black_box(op2.process_event(e).checks);
            }
        },
    );

    // observation capture on/off delta
    let mut op_obs = op.clone();
    op_obs.obs.enabled = false;
    bench(
        &format!("q4.process_event(no-obs, pms={pms})"),
        1,
        10,
        batch.len() as u64,
        || {
            let mut op2 = op_obs.clone();
            for e in &batch {
                black_box(op2.process_event(e).checks);
            }
        },
    );

    // bookkeeping-only path (E-BL dropped events)
    bench(
        &format!("q4.process_bookkeeping(pms={pms})"),
        1,
        10,
        batch.len() as u64,
        || {
            let mut op2 = op.clone();
            for e in &batch {
                black_box(op2.process_bookkeeping(e).opened);
            }
        },
    );

    // dataset generation itself
    bench("stockgen.next_event", 1, 10, 100_000, || {
        let mut g = StockGen::with_seed(9);
        for _ in 0..100_000 {
            black_box(g.next_event());
        }
    });
}
