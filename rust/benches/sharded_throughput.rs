//! Sharded-runtime scaling bench: wall-clock event throughput of the
//! mixed Q1–Q4 workload at 1/2/4 shards, against the single-threaded
//! operator reference — now the PR 4 acceptance bench for the
//! zero-allocation event plane.
//!
//! Three explicit PASS/FAIL gates, recorded into `BENCH_pr4.json`:
//!
//! 1. **Alloc gate** — with the counting global allocator installed,
//!    the pooled + type-routed dispatch plane must perform (amortized)
//!    0 allocations per dispatched event in steady state: warm a
//!    4-shard runtime on the head of the trace, then count allocations
//!    across every thread while the tail streams through.  The gate is
//!    `allocs/event < 0.01` (exactly-zero is unattainable only because
//!    completion batches occasionally outgrow a recycled buffer).
//! 2. **≥1.3× vs the PR 3 dispatch** — the same workload at 4 shards
//!    with `set_pooling(false)` + `set_type_routing(false)`, which is
//!    precisely the PR 3 behavior (fresh `Arc<Vec<Event>>` copy per
//!    dispatch, every shard matches every event), must be at least
//!    1.3× slower than the pooled + routed plane.
//! 3. **≥1.8× scaling at 4 shards vs 1** (the PR 1 target, kept
//!    informational here — the hard gates are 1 and 2).
//!
//! `-- --smoke` runs a tiny configuration for CI: gates 2–3 become
//! informational (too noisy at smoke scale), the alloc gate stays
//! enforced with a looser 0.05 threshold (smaller tail, colder pools).

mod common;

use common::{alloc_count, bench, black_box, emit_json, smoke_mode, BenchResult};
use pspice::datasets::{mixed_queries, mixed_trace};
use pspice::metrics::Throughput;
use pspice::operator::{BatchResult, Operator, OperatorState};
use pspice::runtime::ShardedOperator;

#[global_allocator]
static ALLOC: alloc_count::CountingAllocator = alloc_count::CountingAllocator;

fn main() {
    println!("== sharded_throughput (mixed Q1-Q4, zero-alloc event plane) ==");
    let smoke = smoke_mode();
    let queries = mixed_queries(4_000);
    let n_events = if smoke { 40_000 } else { 200_000 };
    let reps = if smoke { 2 } else { 3 };
    let trace = mixed_trace(n_events, 5);
    let batch = 2_048;
    let mut results: Vec<BenchResult> = Vec::new();

    // Every timed iteration builds a FRESH operator: replaying a trace
    // whose seq/ts restart at 0 into a long-lived operator would leave
    // its old windows unexpirable and accumulate state, so reps 2+
    // would measure a degenerate workload instead of the mixed one.

    // single-threaded operator reference (no channel/merge overhead)
    results.push(bench(
        "operator.process_event(mixed)",
        1,
        reps,
        trace.len() as u64,
        || {
            let mut op = Operator::new(queries.clone());
            op.obs.enabled = false;
            for e in &trace {
                black_box(op.process_event(e));
            }
        },
    ));

    let mut meters: Vec<(usize, Throughput)> = Vec::new();
    for &shards in &[1usize, 2, 4] {
        let r = bench(
            &format!("sharded.process_batch(shards={shards})"),
            1,
            reps,
            trace.len() as u64,
            || {
                let mut sop = ShardedOperator::new(queries.clone(), shards);
                sop.set_obs_enabled(false);
                for chunk in trace.chunks(batch) {
                    black_box(sop.process_batch(chunk));
                }
            },
        );
        let mut t = Throughput::new();
        t.record(trace.len() as u64, r.mean_s);
        meters.push((shards, t));
        results.push(r);
    }

    // the PR 3 dispatch baseline: copy-per-dispatch, no type routing
    let legacy = bench(
        "sharded.process_batch(shards=4, pr3-dispatch)",
        1,
        reps,
        trace.len() as u64,
        || {
            let mut sop = ShardedOperator::new(queries.clone(), 4);
            sop.set_obs_enabled(false);
            sop.set_pooling(false);
            sop.set_type_routing(false);
            for chunk in trace.chunks(batch) {
                black_box(sop.process_batch(chunk));
            }
        },
    );

    let base = meters[0].1;
    for (shards, t) in &meters[1..] {
        println!(
            "  speedup @{shards} shards vs 1: {:.2}x ({:.2} Mevents/s)",
            t.speedup_over(&base),
            t.events_per_sec() / 1e6
        );
    }
    let four = meters
        .iter()
        .find(|(s, _)| *s == 4)
        .expect("4-shard meter")
        .1;
    let pooled_mean = trace.len() as f64 / four.events_per_sec();
    let scaling = four.speedup_over(&base);
    let vs_pr3 = legacy.mean_s / pooled_mean.max(1e-12);
    println!(
        "  target >=1.8x at 4 shards vs 1 [informational]: {} ({scaling:.2}x)",
        if scaling >= 1.8 { "PASS" } else { "FAIL" }
    );
    let vs_pr3_pass = vs_pr3 >= 1.3;
    println!(
        "  target >=1.3x pooled+routed vs PR3 dispatch at 4 shards: {}{} ({vs_pr3:.2}x)",
        if vs_pr3_pass { "PASS" } else { "FAIL" },
        if smoke { " [informational at smoke scale]" } else { "" }
    );
    results.push(BenchResult {
        name: "derived.scaling_4shards_vs_1".to_string(),
        mean_s: scaling,
        stddev_s: 0.0,
        items: 0,
    });
    results.push(BenchResult {
        name: "derived.pooled_routed_vs_pr3_dispatch_4shards".to_string(),
        mean_s: vs_pr3,
        stddev_s: 0.0,
        items: 0,
    });
    results.push(legacy);

    // ---- the alloc gate: steady-state allocations per event ---------
    // One long-lived 4-shard runtime streams the trace once (no
    // replay): the head warms every pool, sink, window shell and
    // channel; the tail is the steady state we count allocations over,
    // across all threads (workers included).  Dispatch goes through the
    // into-buffer API — completions ride ONE recycled BatchResult
    // across every call, so the coordinator boundary itself is
    // allocation-free too (the PR 5 follow-up to the pooled plane).
    let mut sop = ShardedOperator::new(queries.clone(), 4);
    sop.set_obs_enabled(false);
    let split = trace.len() * 3 / 5;
    let mut out = BatchResult::default();
    for chunk in trace[..split].chunks(batch) {
        sop.process_batch_into(chunk, None, &mut out);
        black_box(&out);
    }
    let (a0, b0) = alloc_count::snapshot();
    for chunk in trace[split..].chunks(batch) {
        sop.process_batch_into(chunk, None, &mut out);
        black_box(&out);
    }
    let (a1, b1) = alloc_count::snapshot();
    let tail = (trace.len() - split) as u64;
    let allocs = a1 - a0;
    let bytes = b1 - b0;
    let per_event = allocs as f64 / tail as f64;
    let threshold = if smoke { 0.05 } else { 0.01 };
    let alloc_pass = per_event < threshold;
    println!(
        "  steady-state dispatch: {allocs} allocs / {tail} events = {per_event:.5} allocs/event ({bytes} bytes)"
    );
    println!(
        "  alloc gate (0 allocs per dispatched event, i.e. < {threshold}/event amortized): {}",
        if alloc_pass { "PASS" } else { "FAIL" }
    );
    println!(
        "  (dispatch pool: {} batch buffer(s); {} coordinator-skipped sends)",
        sop.pooled_batches(),
        sop.skipped_dispatches()
    );
    results.push(BenchResult {
        name: format!("derived.steady_state_allocs_per_event(threshold={threshold})"),
        mean_s: per_event,
        stddev_s: 0.0,
        items: tail,
    });
    results.push(BenchResult {
        name: "alloc_gate".to_string(),
        mean_s: if alloc_pass { 1.0 } else { 0.0 },
        stddev_s: 0.0,
        items: allocs,
    });

    if let Err(e) = emit_json("sharded_throughput", &results, "BENCH_pr4.json") {
        eprintln!("warning: could not write bench json: {e}");
    }

    // the alloc gate is scale-independent enough to enforce everywhere;
    // the throughput gate only at full scale (smoke is noise)
    if !alloc_pass {
        std::process::exit(1);
    }
    if !smoke && !vs_pr3_pass {
        std::process::exit(1);
    }
}
