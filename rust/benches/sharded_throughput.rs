//! Sharded-runtime scaling bench: wall-clock event throughput of the
//! mixed Q1–Q4 workload at 1/2/4 shards, against the single-threaded
//! operator reference.
//!
//! The acceptance target for the sharded runtime is ≥1.8× event
//! throughput at 4 shards vs 1 shard on this workload; the bench prints
//! an explicit PASS/FAIL line for it.

mod common;

use common::{bench, black_box};
use pspice::datasets::{mixed_queries, mixed_trace};
use pspice::metrics::Throughput;
use pspice::operator::Operator;
use pspice::runtime::ShardedOperator;

fn main() {
    println!("== sharded_throughput (mixed Q1-Q4) ==");
    let queries = mixed_queries(4_000);
    let trace = mixed_trace(200_000, 5);
    let batch = 2_048;

    // Every iteration builds a FRESH operator: replaying a trace whose
    // seq/ts restart at 0 into a long-lived operator would leave its
    // old windows unexpirable and accumulate state, so reps 2+ would
    // measure a degenerate workload instead of the mixed Q1-Q4 one.

    // single-threaded operator reference (no channel/merge overhead)
    bench(
        "operator.process_event(mixed)",
        1,
        3,
        trace.len() as u64,
        || {
            let mut op = Operator::new(queries.clone());
            op.obs.enabled = false;
            for e in &trace {
                black_box(op.process_event(e));
            }
        },
    );

    let mut meters: Vec<(usize, Throughput)> = Vec::new();
    for &shards in &[1usize, 2, 4] {
        let r = bench(
            &format!("sharded.process_batch(shards={shards})"),
            1,
            3,
            trace.len() as u64,
            || {
                let mut sop = ShardedOperator::new(queries.clone(), shards);
                sop.set_obs_enabled(false);
                for chunk in trace.chunks(batch) {
                    black_box(sop.process_batch(chunk));
                }
            },
        );
        let mut t = Throughput::new();
        t.record(trace.len() as u64, r.mean_s);
        meters.push((shards, t));
    }

    let base = meters[0].1;
    for (shards, t) in &meters[1..] {
        println!(
            "  speedup @{shards} shards vs 1: {:.2}x ({:.2} Mevents/s)",
            t.speedup_over(&base),
            t.events_per_sec() / 1e6
        );
    }
    let four = meters
        .iter()
        .find(|(s, _)| *s == 4)
        .expect("4-shard meter")
        .1;
    let speedup = four.speedup_over(&base);
    println!(
        "  target >=1.8x at 4 shards: {} ({speedup:.2}x)",
        if speedup >= 1.8 { "PASS" } else { "FAIL" }
    );
}
