//! End-to-end wall-clock bench: the full three-phase experiment
//! (Figures 5/6 cells) at a reduced-but-realistic scale, one cell per
//! query family and shedder — the number `make figures` amortizes.

mod common;

use common::bench;
use pspice::config::ExperimentConfig;
use pspice::datasets::DatasetKind;
use pspice::harness::run_experiment;
use pspice::shedding::ShedderKind;

fn cell(query: &str, dataset: DatasetKind, window: u64, n: usize) -> ExperimentConfig {
    ExperimentConfig {
        query: query.into(),
        window,
        pattern_n: n,
        slide: 500,
        dataset,
        seed: 1,
        warmup: 30_000,
        events: 30_000,
        rate: 1.2,
        lb_ms: 0.5,
        shedder: ShedderKind::PSpice,
        model: pspice::model::ModelKind::Markov,
        weights: Vec::new(),
        cost_factors: Vec::new(),
        retrain_every: 0,
        drift_threshold: 0.01,
        shards: 1,
        batch: 256,
        ..ExperimentConfig::default()
    }
}

fn main() {
    println!("== end_to_end (one Fig-5 cell per family) ==");
    let cells = [
        cell("q1", DatasetKind::Stock, 5_000, 0),
        cell("q2", DatasetKind::Stock, 7_500, 0),
        cell("q3", DatasetKind::Soccer, 1_500, 4),
        cell("q4", DatasetKind::Bus, 2_000, 4),
    ];
    for cfg in &cells {
        for shedder in [ShedderKind::PSpice, ShedderKind::PmBaseline, ShedderKind::EventBaseline] {
            let mut c = cfg.clone();
            c.shedder = shedder;
            let label = format!("{}.{:?}", c.query, shedder);
            bench(&label, 0, 3, (c.warmup + c.events) * 2, || {
                let r = run_experiment(&c).expect("experiment");
                assert!(r.truth_total > 0);
            });
        }
    }
}
