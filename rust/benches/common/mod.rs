//! Tiny bench harness (criterion is not in the offline crate set):
//! warm-up + repeated timed runs, reporting mean ± stddev and
//! throughput.  Used by every `harness = false` bench target.

use std::time::Instant;

/// One benchmark measurement.
pub struct BenchResult {
    /// label
    pub name: String,
    /// mean seconds per iteration
    pub mean_s: f64,
    /// stddev of seconds per iteration
    pub stddev_s: f64,
    /// items processed per iteration (for throughput)
    pub items: u64,
}

impl BenchResult {
    /// Human line, criterion-ish.
    pub fn report(&self) {
        let per_item = if self.items > 0 {
            format!(
                "  {:>12.1} ns/item  {:>12.2} Mitems/s",
                self.mean_s * 1e9 / self.items as f64,
                self.items as f64 / self.mean_s / 1e6
            )
        } else {
            String::new()
        };
        println!(
            "{:<44} {:>10.3} ms ± {:>8.3} ms{}",
            self.name,
            self.mean_s * 1e3,
            self.stddev_s * 1e3,
            per_item
        );
    }
}

/// Run `f` (which processes `items` items) `reps` times after `warmup`
/// unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, items: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples
        .iter()
        .map(|s| (s - mean) * (s - mean))
        .sum::<f64>()
        / samples.len().max(1) as f64;
    let r = BenchResult {
        name: name.to_string(),
        mean_s: mean,
        stddev_s: var.sqrt(),
        items,
    };
    r.report();
    r
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
