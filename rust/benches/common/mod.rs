//! Tiny bench harness (criterion is not in the offline crate set):
//! warm-up + repeated timed runs, reporting mean ± stddev and
//! throughput, plus machine-readable emission into a per-PR
//! `BENCH_*.json` so CI's perf-smoke job (and humans diffing runs) can
//! consume the numbers without scraping stdout, and a counting global
//! allocator benches opt into to *prove* a hot path allocation-free.

use std::time::Instant;

/// A counting wrapper around the system allocator.  A bench binary
/// opts in with
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: common::alloc_count::CountingAllocator =
///     common::alloc_count::CountingAllocator;
/// ```
///
/// and brackets the measured region with [`alloc_count::snapshot`]
/// calls; the delta is the number of heap allocations (allocs +
/// reallocs) the region performed, across *all* threads — worker
/// shards included, which is the point.
#[allow(dead_code)]
pub mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    /// The counting allocator (zero-sized; counters are globals).
    pub struct CountingAllocator;

    static ALLOCS: AtomicU64 = AtomicU64::new(0);
    static BYTES: AtomicU64 = AtomicU64::new(0);

    unsafe impl GlobalAlloc for CountingAllocator {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
            System.alloc(layout)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
            System.alloc_zeroed(layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
    }

    /// `(allocations, bytes)` counted since process start.
    pub fn snapshot() -> (u64, u64) {
        (ALLOCS.load(Ordering::Relaxed), BYTES.load(Ordering::Relaxed))
    }
}

/// One benchmark measurement.
pub struct BenchResult {
    /// label
    pub name: String,
    /// mean seconds per iteration
    pub mean_s: f64,
    /// stddev of seconds per iteration
    pub stddev_s: f64,
    /// items processed per iteration (for throughput)
    pub items: u64,
}

impl BenchResult {
    /// Human line, criterion-ish.
    pub fn report(&self) {
        let per_item = if self.items > 0 {
            format!(
                "  {:>12.1} ns/item  {:>12.2} Mitems/s",
                self.mean_s * 1e9 / self.items as f64,
                self.items as f64 / self.mean_s / 1e6
            )
        } else {
            String::new()
        };
        println!(
            "{:<44} {:>10.3} ms ± {:>8.3} ms{}",
            self.name,
            self.mean_s * 1e3,
            self.stddev_s * 1e3,
            per_item
        );
    }
}

/// Run `f` (which processes `items` items) `reps` times after `warmup`
/// unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, items: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples
        .iter()
        .map(|s| (s - mean) * (s - mean))
        .sum::<f64>()
        / samples.len().max(1) as f64;
    let r = BenchResult {
        name: name.to_string(),
        mean_s: mean,
        stddev_s: var.sqrt(),
        items,
    };
    r.report();
    r
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Was the bench invoked with `-- --smoke` (CI's tiny-config mode)?
#[allow(dead_code)]
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Minimal JSON string escaping (bench labels are plain ASCII, but be
/// correct anyway).
#[allow(dead_code)]
fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Emit `results` as the `bench` section of the machine-readable
/// results file (`$BENCH_JSON`, falling back to `default_path` in the
/// bench working directory — the `rust/` package root under cargo;
/// each PR's acceptance benches pick their own default, e.g.
/// `BENCH_pr3.json` / `BENCH_pr4.json`).
///
/// The file is a single JSON object stamped with a schema marker the
/// scorecard's bench-gate folding validates, plus one array per bench
/// target, each section kept on its own line; re-running one bench
/// replaces only its own section, so `shed_overhead` and
/// `operator_throughput` can both record into the same file:
///
/// ```json
/// {
///   "schema": "pspice-bench-v1",
///   "shed_overhead": [{"name": "...", "mean_s": ..., "stddev_s": ..., "items": ..., "items_per_s": ...}],
///   "operator_throughput": [...]
/// }
/// ```
#[allow(dead_code)]
pub fn emit_json(
    bench: &str,
    results: &[BenchResult],
    default_path: &str,
) -> std::io::Result<String> {
    let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| default_path.to_string());
    // the schema marker always leads; keep every other bench's
    // single-line section
    let mut sections: Vec<(String, String)> =
        vec![("schema".to_string(), "\"pspice-bench-v1\"".to_string())];
    if let Ok(existing) = std::fs::read_to_string(&path) {
        for line in existing.lines() {
            let t = line.trim().trim_end_matches(',');
            if let Some(rest) = t.strip_prefix('"') {
                if let Some((name, body)) = rest.split_once("\": ") {
                    if name != bench && name != "schema" {
                        sections.push((name.to_string(), body.to_string()));
                    }
                }
            }
        }
    }
    let entries: Vec<String> = results
        .iter()
        .map(|r| {
            let items_per_s = if r.mean_s > 0.0 {
                r.items as f64 / r.mean_s
            } else {
                0.0
            };
            format!(
                "{{\"name\": \"{}\", \"mean_s\": {:e}, \"stddev_s\": {:e}, \"items\": {}, \"items_per_s\": {:e}}}",
                escape(&r.name),
                r.mean_s,
                r.stddev_s,
                r.items,
                items_per_s
            )
        })
        .collect();
    sections.push((bench.to_string(), format!("[{}]", entries.join(", "))));
    let body: Vec<String> = sections
        .iter()
        .map(|(name, body)| format!("  \"{}\": {}", escape(name), body))
        .collect();
    std::fs::write(&path, format!("{{\n{}\n}}\n", body.join(",\n")))?;
    println!("(bench results recorded in {path})");
    Ok(path)
}
