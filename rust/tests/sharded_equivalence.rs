//! Sharded-vs-unsharded equivalence (the sharded runtime's core
//! contract): for seeded streams, a sharded run emits the *identical*
//! complex-event set as the single-threaded operator, and globally
//! ordered shedding picks the same victims whether the queries live on
//! one shard or four.

use pspice::datasets::{mixed_queries, mixed_trace, BusGen, StockGen};
use pspice::events::{Event, EventStream};
use pspice::model::{ModelBuilder, ModelConfig};
use pspice::operator::{ComplexEvent, Operator};
use pspice::query::builtin::{q1, q4};
use pspice::query::Query;
use pspice::runtime::sharded::sort_completions;
use pspice::runtime::ShardedOperator;
use pspice::testing::forall;

fn unsharded_completions(queries: &[Query], events: &[Event]) -> (Vec<ComplexEvent>, usize) {
    let mut op = Operator::new(queries.to_vec());
    let mut out = Vec::new();
    for e in events {
        out.extend(op.process_event(e).completions);
    }
    sort_completions(&mut out);
    (out, op.pm_count())
}

fn sharded_completions(
    queries: &[Query],
    events: &[Event],
    shards: usize,
    batch: usize,
) -> (Vec<ComplexEvent>, usize) {
    let mut sop = ShardedOperator::new(queries.to_vec(), shards);
    let mut out = Vec::new();
    for chunk in events.chunks(batch) {
        out.extend(sop.process_batch(chunk).completions);
    }
    (out, sop.pm_count())
}

#[test]
fn prop_sharded_equals_unsharded_on_seeded_streams() {
    // property style: random query geometry, seed, shard count and
    // batch size over both the Bus and the Stock stream families
    forall(6, 1231, |g| {
        let use_bus = g.bool(0.5);
        let (queries, events) = if use_bus {
            let n = g.usize(3, 5);
            let ws = g.usize(1_000, 3_000) as u64;
            let slide = g.usize(100, 500) as u64;
            let mut gen = BusGen::with_seed(g.usize(0, 1 << 20) as u64);
            (q4(n, ws, slide).queries, gen.take_events(g.usize(4_000, 9_000)))
        } else {
            let ws = g.usize(800, 2_500) as u64;
            let mut gen = StockGen::with_seed(g.usize(0, 1 << 20) as u64);
            (q1(ws).queries, gen.take_events(g.usize(4_000, 9_000)))
        };
        let shards = g.usize(2, 4);
        let batch = g.usize(64, 800);
        let (expected, expected_pms) = unsharded_completions(&queries, &events);
        let (got, got_pms) = sharded_completions(&queries, &events, shards, batch);
        assert_eq!(
            got, expected,
            "completions diverged (shards={shards} batch={batch})"
        );
        assert_eq!(got_pms, expected_pms, "PM counts diverged");
    });
}

#[test]
fn mixed_q1_q4_workload_sharded_matches_unsharded() {
    let queries = mixed_queries(2_000);
    let trace = mixed_trace(30_000, 11);
    let (expected, expected_pms) = unsharded_completions(&queries, &trace);
    for shards in [2, 4] {
        let (got, got_pms) = sharded_completions(&queries, &trace, shards, 512);
        assert_eq!(got, expected, "shards={shards}");
        assert_eq!(got_pms, expected_pms, "shards={shards}");
    }
    if expected.is_empty() {
        // equality still covers window/PM evolution, but flag vacuity
        eprintln!("note: mixed workload produced no complex events at this scale");
    }
}

#[test]
fn global_shedding_picks_identical_victims_across_shard_counts() {
    // drive identical shed decisions (every 4th batch, fixed rho) on a
    // 1-shard and a 4-shard runtime: Alg. 2's "drop the rho globally
    // lowest-utility PMs" must select the same victims, so completions
    // AND post-shed PM counts stay identical
    let queries = mixed_queries(2_000);
    let trace = mixed_trace(40_000, 13);

    // utility tables from an unsharded warm-up operator
    let mut warm = Operator::new(queries.clone());
    for e in &trace[..20_000] {
        warm.process_event(e);
    }
    let mut mb = ModelBuilder::new(
        ModelConfig {
            eta: 100,
            max_bins: 64,
            use_tau: true,
        },
        Box::new(pspice::runtime::FallbackEngine),
    );
    let tables = mb.build(&warm).unwrap();

    let run = |shards: usize| -> (Vec<ComplexEvent>, Vec<usize>) {
        let mut sop = ShardedOperator::new(queries.clone(), shards);
        sop.set_tables(&tables);
        let mut ces = Vec::new();
        let mut pm_counts = Vec::new();
        for (i, chunk) in trace.chunks(500).enumerate() {
            ces.extend(sop.process_batch(chunk).completions);
            if i % 4 == 3 {
                let shed = sop.shed_lowest(40);
                assert_eq!(shed.dropped, shed.scanned.min(40));
                pm_counts.push(sop.pm_count());
            }
        }
        (ces, pm_counts)
    };

    let (ces1, counts1) = run(1);
    let (ces4, counts4) = run(4);
    assert_eq!(counts1, counts4, "post-shed PM counts diverged");
    assert_eq!(ces1, ces4, "complex-event sets diverged under shedding");
    assert!(
        counts1.iter().any(|&c| c > 0),
        "shedding runs never had live PMs — the scenario is vacuous"
    );
}

#[test]
fn bus_only_trace_with_foreign_shards_matches_single_and_unrouted() {
    // the ISSUE-4 satellite workload: the mixed eight-query set fed a
    // bus-only trace, so the shards hosting only stock/soccer queries
    // never see a relevant event — type routing must skim (or skip)
    // them without changing a single completion, drop, or PM count
    let queries = mixed_queries(1_500);
    let events: Vec<Event> = {
        let mut g = BusGen::with_seed(19);
        g.take_events(20_000)
            .into_iter()
            .map(|mut e| {
                e.etype += pspice::datasets::mixed::BUS_BASE;
                e
            })
            .collect()
    };

    // single-threaded reference (routing on — the unit suite pins
    // routed-vs-unrouted equality on the operator itself)
    let mut single = Operator::new(queries.clone());
    let mut expected = Vec::new();
    let mut expected_sheds = Vec::new();
    for (i, chunk) in events.chunks(512).enumerate() {
        for e in chunk {
            expected.extend(single.process_event(e).completions);
        }
        if i % 5 == 4 {
            let out = single.shed_lowest(30);
            expected_sheds.push((out.dropped, single.pm_count()));
        }
    }
    sort_completions(&mut expected);
    assert!(
        expected_sheds.iter().any(|&(d, _)| d > 0),
        "scenario must actually shed"
    );

    for shards in [2usize, 4] {
        for routing in [true, false] {
            let mut sop = ShardedOperator::new(queries.clone(), shards);
            sop.set_type_routing(routing);
            let mut got = Vec::new();
            let mut sheds = Vec::new();
            for (i, chunk) in events.chunks(512).enumerate() {
                got.extend(sop.process_batch(chunk).completions);
                if i % 5 == 4 {
                    let out = sop.shed_lowest(30);
                    sheds.push((out.dropped, sop.pm_count()));
                }
            }
            sort_completions(&mut got);
            assert_eq!(
                got, expected,
                "completions diverged (shards={shards} routing={routing})"
            );
            assert_eq!(
                sheds, expected_sheds,
                "shed trail diverged (shards={shards} routing={routing})"
            );
            assert_eq!(sop.pm_count(), single.pm_count());
            if !routing {
                assert_eq!(sop.skipped_dispatches(), 0);
            }
        }
    }
}

#[test]
fn shed_lowest_budget_is_exact_on_mixed_workload() {
    let queries = mixed_queries(2_000);
    let trace = mixed_trace(24_000, 17);
    let mut warm = Operator::new(queries.clone());
    for e in &trace {
        warm.process_event(e);
    }
    let mut mb = ModelBuilder::new(
        ModelConfig {
            eta: 100,
            max_bins: 64,
            use_tau: true,
        },
        Box::new(pspice::runtime::FallbackEngine),
    );
    let tables = mb.build(&warm).unwrap();

    let mut sop = ShardedOperator::new(queries, 3);
    sop.set_tables(&tables);
    for chunk in trace.chunks(512) {
        sop.process_batch(chunk);
    }
    let before = sop.pm_count();
    assert!(before > 50, "need a PM population, got {before}");
    let rho = before / 3;
    let shed = sop.shed_lowest(rho);
    assert_eq!(shed.scanned, before);
    assert_eq!(shed.dropped, rho);
    assert_eq!(sop.pm_count(), before - rho);
}
