//! Chaos suite: deterministic fault injection against the sharded
//! real-time plane.
//!
//! A seeded `FaultPlan` kills each of the four shard workers once
//! mid-overload.  The run must complete with every event accounted
//! for, every dead worker respawned, and the latency tail in the same
//! regime as the fault-free run.  Without checkpointing the lost
//! partial matches are booked as involuntary shedding
//! (`dropped_pms_failure`): recovery is bounded-latency, and a crash
//! costs result quality, never the latency bound.  With the checkpoint
//! plane armed (`checkpoint_every > 0`) the same kills recover all
//! state via snapshot + journal replay instead: `dropped_pms_failure`
//! stays 0, the restored PMs are booked as `recovered_pms`, and the
//! run's detections match the fault-free run exactly.
//!
//! Everything here runs on the virtual clock, so every assertion is
//! deterministic per seed: two identical runs must agree bit-for-bit,
//! which is also what lets CI trend `dropped_pms_failure`.

use pspice::config::ExperimentConfig;
use pspice::datasets::DatasetKind;
use pspice::harness::run_realtime_experiment;
use pspice::ingest::SourceKind;
use pspice::shedding::{OverloadKind, ShedderKind};

fn chaos_cfg() -> ExperimentConfig {
    ExperimentConfig {
        // four queries so the run actually has four shards to kill
        query: "q1+q2".into(),
        window: 1_500,
        dataset: DatasetKind::Stock,
        seed: 11,
        events: 10_000,
        warmup: 12_000,
        rate: 1.4,
        lb_ms: 0.05,
        shedder: ShedderKind::PSpice,
        shards: 4,
        batch: 64,
        source: SourceKind::Oscillate,
        overload: OverloadKind::Measured,
        ..ExperimentConfig::default()
    }
}

/// Kill each of the four shards once, staggered.  Dispatch counts are
/// cumulative from priming: the 12k-event warm-up prefix consumes ~188
/// dispatches at batch 64, so these indices land in the measured
/// overload phase, with every shard holding live PMs.
const KILL_EACH_SHARD_ONCE: &str = "kill:0@200,kill:1@215,kill:2@230,kill:3@245";

#[test]
fn every_shard_killed_once_run_completes_in_the_same_latency_regime() {
    let clean = run_realtime_experiment(&chaos_cfg(), None, false).unwrap();
    let mut cfg = chaos_cfg();
    cfg.faults = KILL_EACH_SHARD_ONCE.into();
    let faulty = run_realtime_experiment(&cfg, None, false).unwrap();

    assert_eq!(clean.recoveries, 0);
    assert_eq!(clean.dropped_pms_failure, 0);
    assert_eq!(faulty.recoveries, 4, "each shard killed and respawned once");
    assert!(
        faulty.dropped_pms_failure > 0,
        "mid-overload the dead shards held PMs, and losing them is shedding"
    );

    // recovery never loses *events*: the coordinator keeps dispatching
    // and the latency accounting covers the whole stream either way
    assert_eq!(faulty.events_processed(), clean.events_processed());
    assert_eq!(faulty.events_processed(), 10_000);

    // bounded-latency recovery: the faulty run's tail stays in the
    // regime the fault-free run demonstrates — inside the bound, or
    // within a small factor of the fault-free tail when the workload
    // itself runs above it.  (Respawn cost is real time, not virtual
    // time, so on this clock any tail growth would mean recovery
    // perturbed the shedding loop itself.)
    let lb_ns = faulty.lb_ms * 1e6;
    assert!(
        faulty.latency.p95_ns() <= lb_ns.max(clean.latency.p95_ns() * 1.25),
        "recovery blew up the tail: faulty p95 {} ns vs clean p95 {} ns (LB {} ns)",
        faulty.latency.p95_ns(),
        clean.latency.p95_ns(),
        lb_ns
    );
    assert!(
        faulty.latency.violation_rate() <= clean.latency.violation_rate() + 0.05,
        "recovery must not add violations: {} vs {}",
        faulty.latency.violation_rate(),
        clean.latency.violation_rate()
    );
}

#[test]
fn failure_accounting_is_deterministic_per_seed() {
    let mut cfg = chaos_cfg();
    cfg.faults = KILL_EACH_SHARD_ONCE.into();
    let a = run_realtime_experiment(&cfg, None, false).unwrap();
    let b = run_realtime_experiment(&cfg, None, false).unwrap();

    assert_eq!(a.recoveries, 4);
    assert_eq!(a.recoveries, b.recoveries);
    assert_eq!(a.dropped_pms_failure, b.dropped_pms_failure);
    assert_eq!(a.dropped_pms, b.dropped_pms);
    assert_eq!(a.completions, b.completions);
    assert_eq!(a.latency.stats.count(), b.latency.stats.count());
    assert_eq!(a.latency.violations, b.latency.violations);
    assert_eq!(
        a.latency.stats.mean().to_bits(),
        b.latency.stats.mean().to_bits(),
        "mean latency diverged across identical chaos runs"
    );
    assert_eq!(
        a.latency.stats.max().to_bits(),
        b.latency.stats.max().to_bits(),
        "max latency diverged across identical chaos runs"
    );
}

#[test]
fn non_fatal_faults_leave_the_virtual_measurement_bit_exact() {
    // a delayed response stalls the wall clock, not the virtual one:
    // with the fault machinery armed but nothing killed, every number
    // must match the plain run exactly (the zero-fault regression pin
    // one level up from `ShardedOperator`'s own empty-plan test)
    let clean = run_realtime_experiment(&chaos_cfg(), None, false).unwrap();
    let mut cfg = chaos_cfg();
    cfg.faults = "delay:1@190:0.5".into();
    let delayed = run_realtime_experiment(&cfg, None, false).unwrap();

    assert_eq!(delayed.recoveries, 0, "a delay is not a failure");
    assert_eq!(delayed.dropped_pms_failure, 0);
    assert_eq!(delayed.completions, clean.completions);
    assert_eq!(delayed.dropped_pms, clean.dropped_pms);
    assert_eq!(delayed.peak_pms, clean.peak_pms);
    assert_eq!(
        delayed.latency.stats.mean().to_bits(),
        clean.latency.stats.mean().to_bits(),
        "a non-fatal fault changed the virtual timeline"
    );
    assert_eq!(delayed.latency.violations, clean.latency.violations);
}

#[test]
fn repeated_kills_of_the_same_shard_respawn_every_time() {
    let mut cfg = chaos_cfg();
    cfg.faults = "kill:2@200,kill:2@230,kill:2@260".into();
    let res = run_realtime_experiment(&cfg, None, false).unwrap();
    assert_eq!(res.recoveries, 3, "every kill of shard 2 must respawn it");
    assert!(res.dropped_pms_failure > 0);
    assert_eq!(res.events_processed(), 10_000);
}

/// An under-capacity, no-shedding configuration: with no strategy in
/// the loop, detections are a pure function of the event stream, so a
/// checkpointed chaos run can be compared against the clean run
/// *exactly* — any lost or invented completion is a recovery bug.
fn recovery_cfg() -> ExperimentConfig {
    ExperimentConfig {
        query: "q1+q2".into(),
        window: 1_500,
        dataset: DatasetKind::Stock,
        seed: 11,
        events: 10_000,
        warmup: 12_000,
        rate: 0.5,
        lb_ms: 2.0,
        shedder: ShedderKind::None,
        shards: 4,
        batch: 64,
        checkpoint_every: 8,
        journal_cap: 20_000,
        ..ExperimentConfig::default()
    }
}

#[test]
fn checkpointed_kills_of_every_shard_lose_no_state() {
    let clean = run_realtime_experiment(&recovery_cfg(), None, false).unwrap();
    assert_eq!(clean.recoveries, 0);
    assert_eq!(clean.recovered_pms, 0);

    let mut cfg = recovery_cfg();
    cfg.faults = KILL_EACH_SHARD_ONCE.into();
    let ck = run_realtime_experiment(&cfg, None, false).unwrap();

    assert_eq!(ck.recoveries, 4, "each shard killed and respawned once");
    assert_eq!(
        ck.dropped_pms_failure, 0,
        "snapshot + journal replay must not lose a single PM"
    );
    assert!(ck.recovered_pms > 0, "the dead shards held PMs to restore");
    assert!(ck.replayed_events > 0, "restores replay the journal tail");
    assert_eq!(ck.hangs_detected, 0);
    assert_eq!(ck.events_processed(), 10_000);
    // QoR matches the clean run exactly: every completion the dead
    // workers would have produced is recovered or replayed
    assert_eq!(ck.completions, clean.completions, "recovery changed QoR");

    // the lossy baseline on the same fault schedule pays in state
    cfg.checkpoint_every = 0;
    let lossy = run_realtime_experiment(&cfg, None, false).unwrap();
    assert_eq!(lossy.recoveries, 4);
    assert!(lossy.dropped_pms_failure > 0, "lossy recovery drops PMs");
    assert_eq!(lossy.recovered_pms, 0);
}

#[test]
fn checkpointed_recovery_is_deterministic_per_seed() {
    let mut cfg = recovery_cfg();
    cfg.faults = KILL_EACH_SHARD_ONCE.into();
    let a = run_realtime_experiment(&cfg, None, false).unwrap();
    let b = run_realtime_experiment(&cfg, None, false).unwrap();
    assert_eq!(a.recovered_pms, b.recovered_pms);
    assert_eq!(a.replayed_events, b.replayed_events);
    assert_eq!(a.completions, b.completions);
    assert_eq!(a.dropped_pms_failure, 0);
    assert_eq!(b.dropped_pms_failure, 0);
}

#[test]
fn injected_hang_is_detected_within_the_deadline_and_recovered() {
    // the hang fault sleeps far past any deadline instead of crashing;
    // with an explicit worker deadline the coordinator must detect it,
    // detach the stuck thread, and (checkpointing on) restore the
    // shard without losing state.  The deadline is wall time even on
    // the virtual clock, so the run stalls ~deadline ms once and then
    // completes.
    let clean = run_realtime_experiment(&recovery_cfg(), None, false).unwrap();
    let mut cfg = recovery_cfg();
    cfg.faults = "hang:1@210".into();
    cfg.worker_deadline_ms = 200.0;
    let res = run_realtime_experiment(&cfg, None, false).unwrap();
    assert_eq!(res.hangs_detected, 1, "the hang must be detected");
    assert_eq!(res.recoveries, 1, "a detected hang recovers like a crash");
    assert_eq!(res.dropped_pms_failure, 0, "checkpointing keeps the state");
    assert!(res.recovered_pms > 0);
    assert_eq!(res.events_processed(), 10_000);
    assert_eq!(res.completions, clean.completions, "hang recovery changed QoR");
}
