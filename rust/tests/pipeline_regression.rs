//! Redesign regression: the `Pipeline`-based `run_experiment` at
//! `shards = 1` must reproduce the pre-redesign single-threaded
//! measurement loop *byte-identically* on the tiny config.
//!
//! The `legacy` module below is a faithful transcription of the old
//! `harness::experiment::measure_single` path (per-event dispatch,
//! `Shedder::on_event`-style inline pSPICE with shedder-owned utility
//! tables and per-PM victim selection), built only from public engine
//! primitives.  Victim selection follows the engine's documented
//! deterministic order `(utility, query, open_seq, state, window
//! position)` — see `operator::cell_cmp` — which the cell-based
//! `shed_lowest` must reproduce PM-for-PM.  Every float is compared
//! through `to_bits`, so any drift in operation order fails loudly.

use std::collections::HashSet;

use pspice::config::ExperimentConfig;
use pspice::datasets::DatasetKind;
use pspice::harness::experiment::{build_queries, build_trace};
use pspice::harness::run_experiment;
use pspice::metrics::{LatencyTracker, QorAccounting};
use pspice::model::{ModelBuilder, ModelConfig};
use pspice::operator::Operator;
use pspice::shedding::{OverloadDetector, ShedderKind};
use pspice::sim::{RateSource, SimClock};

fn tiny_cfg() -> ExperimentConfig {
    ExperimentConfig {
        query: "q4".into(),
        window: 2_000,
        pattern_n: 4,
        slide: 250,
        dataset: DatasetKind::Bus,
        seed: 3,
        events: 20_000,
        warmup: 20_000,
        rate: 1.4,
        lb_ms: 0.05,
        shedder: ShedderKind::PSpice,
        model: pspice::model::ModelKind::Markov,
        weights: Vec::new(),
        cost_factors: Vec::new(),
        retrain_every: 0,
        drift_threshold: 0.01,
        shards: 1,
        batch: 256,
        ..ExperimentConfig::default()
    }
}

/// What the legacy loop measures (the comparable subset of
/// `ExperimentResult`).
struct LegacyResult {
    fn_percent: f64,
    false_positives: usize,
    truth_total: usize,
    capacity_ns: f64,
    dropped_pms: u64,
    peak_pms: usize,
    shed_overhead: f64,
    latency: LatencyTracker,
}

/// The pre-redesign three-phase runner, transcribed.
fn legacy_run(cfg: &ExperimentConfig) -> LegacyResult {
    let queries = build_queries(cfg).unwrap();
    let trace = build_trace(cfg);
    let lb_ns = cfg.lb_ms * 1e6;
    let warmup = (cfg.warmup as usize).min(trace.len());

    // ---- phase 1: ground truth (unchanged by the redesign) ---------
    let mut truth_op = Operator::new(queries.clone());
    truth_op.obs.enabled = false;
    let weights: Vec<f64> = queries.iter().map(|q| q.weight).collect();
    let mut qor = QorAccounting::new(weights, cfg.warmup);
    let mut cost_sum = 0.0;
    let mut cost_n = 0u64;
    let skip = trace.len() / 10;
    for (i, e) in trace.iter().enumerate() {
        let out = truth_op.process_event(e);
        for ce in &out.completions {
            qor.add_truth(ce);
        }
        if i >= skip {
            cost_sum += out.cost_ns;
            cost_n += 1;
        }
    }
    let capacity_ns = cost_sum / cost_n.max(1) as f64;

    // ---- phase 2: calibrate + train (as the old runner did) --------
    let mut op = Operator::new(queries);
    let mut detector = OverloadDetector::new(lb_ns, 0.02 * lb_ns);
    for e in &trace[..warmup] {
        let n_before = op.pm_count();
        let out = op.process_event(e);
        for ce in &out.completions {
            qor.add_detected(ce); // warm-up completions are out of scope anyway
        }
        detector.observe_processing(n_before, out.cost_ns);
    }
    assert!(detector.fit());
    // mirror the harness exactly: the shed-decision scan is priced per
    // *cell*, so the seeded PM counts convert through EST_PMS_PER_CELL
    for n in [100usize, 1_000, 5_000, 20_000, 50_000] {
        let cells = (n as f64 / pspice::operator::EST_PMS_PER_CELL) as usize;
        detector.observe_shedding(n, op.cost.shed_ns(cells, n / 10));
    }
    detector.fit();
    let mut builder = ModelBuilder::with_auto_engine(ModelConfig::default());
    let tables = builder.build(&op).unwrap();

    // ---- phase 3: the old per-event measurement loop ---------------
    op.obs.enabled = false; // no retraining on the tiny config
    let mut clock = SimClock::new();
    let source = RateSource::from_capacity(capacity_ns, cfg.rate, 0.0);
    let mut latency = LatencyTracker::new(lb_ns, (cfg.events / 2_000).max(1));
    let mut shed_ns = 0.0;
    let mut busy_ns = 0.0;
    let mut dropped_pms = 0u64;
    let mut peak_pms = 0usize;
    // the old PSpiceShedder's scratch state.  Keyed by the engine's
    // deterministic per-PM selection order: utility first, then the
    // sharding-invariant cell identity (query, open_seq, state), then
    // window position (pm_refs enumeration order encodes it).
    let mut scratch = Vec::new();
    let mut keyed: Vec<(f64, usize, u64, u32, usize, u64)> = Vec::new();
    for (i, e) in trace[warmup..].iter().enumerate() {
        let arrival = source.arrival_ns(i as u64);
        let l_q = clock.begin_service(arrival);
        // inline Shedder::on_event for pSPICE (old Alg. 1 + Alg. 2)
        let mut shed_cost = 0.0;
        if let Some(rho) = detector.check(l_q, op.pm_count()) {
            op.pm_refs(&mut scratch);
            let n = scratch.len();
            if n > 0 && rho > 0 {
                let rho = rho.min(n);
                keyed.clear();
                keyed.reserve(n);
                for (idx, r) in scratch.iter().enumerate() {
                    keyed.push((
                        tables[r.query].lookup(r.state, r.remaining),
                        r.query,
                        r.open_seq,
                        r.state,
                        idx,
                        r.pm_id,
                    ));
                }
                if rho < n {
                    keyed.select_nth_unstable_by(rho - 1, |a, b| {
                        a.0
                            .total_cmp(&b.0)
                            .then_with(|| a.1.cmp(&b.1))
                            .then_with(|| a.2.cmp(&b.2))
                            .then_with(|| a.3.cmp(&b.3))
                            .then_with(|| a.4.cmp(&b.4))
                    });
                }
                let mut ids: Vec<u64> = keyed[..rho].iter().map(|k| k.5).collect();
                ids.sort_unstable();
                // the engine prices the decision scan per *cell* (the
                // distinct (query, window, state) triples with live
                // PMs), while g() still regresses on the PM population
                let n_cells = scratch
                    .iter()
                    .map(|r| (r.query, r.open_seq, r.state))
                    .collect::<HashSet<_>>()
                    .len();
                let dropped = op.drop_pms(&ids);
                dropped_pms += dropped as u64;
                shed_cost = op.cost.shed_ns(n_cells, dropped);
                detector.observe_shedding(n, shed_cost);
            }
        }
        clock.advance(shed_cost);
        shed_ns += shed_cost;
        busy_ns += shed_cost;
        let out = op.process_event(e);
        clock.advance(out.cost_ns);
        busy_ns += out.cost_ns;
        for ce in &out.completions {
            qor.add_detected(ce);
        }
        latency.record(clock.now_ns(), clock.now_ns() - arrival);
        peak_pms = peak_pms.max(op.pm_count());
    }

    LegacyResult {
        fn_percent: qor.fn_percent(),
        false_positives: qor.false_positives(),
        truth_total: qor.truth_total(),
        capacity_ns,
        dropped_pms,
        peak_pms,
        shed_overhead: if busy_ns > 0.0 { shed_ns / busy_ns } else { 0.0 },
        latency,
    }
}

#[test]
fn pipeline_reproduces_legacy_single_threaded_metrics_bit_for_bit() {
    let cfg = tiny_cfg();
    let legacy = legacy_run(&cfg);
    let new = run_experiment(&cfg).unwrap();

    assert!(legacy.dropped_pms > 0, "scenario must actually shed");
    assert_eq!(new.shedder, "pspice");
    assert_eq!(new.shards, 1);

    assert_eq!(new.truth_total, legacy.truth_total);
    assert_eq!(new.false_positives, legacy.false_positives);
    assert_eq!(new.dropped_pms, legacy.dropped_pms);
    assert_eq!(new.dropped_events, 0);
    assert_eq!(new.peak_pms, legacy.peak_pms);

    assert_eq!(
        new.capacity_ns.to_bits(),
        legacy.capacity_ns.to_bits(),
        "capacity diverged: {} vs {}",
        new.capacity_ns,
        legacy.capacity_ns
    );
    assert_eq!(
        new.fn_percent.to_bits(),
        legacy.fn_percent.to_bits(),
        "fn% diverged: {} vs {}",
        new.fn_percent,
        legacy.fn_percent
    );
    assert_eq!(
        new.shed_overhead.to_bits(),
        legacy.shed_overhead.to_bits(),
        "overhead diverged: {} vs {}",
        new.shed_overhead,
        legacy.shed_overhead
    );

    // latency trace: same sample count, same violations, identical
    // aggregate statistics down to the last bit
    assert_eq!(new.latency.stats.count(), legacy.latency.stats.count());
    assert_eq!(new.latency.violations, legacy.latency.violations);
    assert_eq!(
        new.latency.stats.mean().to_bits(),
        legacy.latency.stats.mean().to_bits(),
        "mean latency diverged: {} vs {}",
        new.latency.stats.mean(),
        legacy.latency.stats.mean()
    );
    assert_eq!(
        new.latency.stats.max().to_bits(),
        legacy.latency.stats.max().to_bits(),
        "max latency diverged: {} vs {}",
        new.latency.stats.max(),
        legacy.latency.stats.max()
    );
    assert_eq!(new.latency.trace, legacy.latency.trace, "plot traces diverged");
}

#[test]
fn pipeline_run_is_deterministic_across_invocations() {
    let a = run_experiment(&tiny_cfg()).unwrap();
    let b = run_experiment(&tiny_cfg()).unwrap();
    assert_eq!(a.fn_percent.to_bits(), b.fn_percent.to_bits());
    assert_eq!(a.dropped_pms, b.dropped_pms);
    assert_eq!(a.peak_pms, b.peak_pms);
    assert_eq!(a.latency.violations, b.latency.violations);
}

#[test]
fn explicit_sim_clock_reproduces_the_default_clock_bit_for_bit() {
    // the clock abstraction must be invisible: a pipeline handed an
    // explicit `SimClock` trait object produces the same floats as one
    // using the implicit default
    let cfg = tiny_cfg();
    let queries = build_queries(&cfg).unwrap();
    let trace = build_trace(&cfg);
    let events = trace[..10_000].to_vec();
    let run = |explicit: bool| {
        let mut b = pspice::pipeline::Pipeline::builder()
            .queries(queries.clone())
            .latency_bound_ms(cfg.lb_ms)
            .arrivals(RateSource::from_capacity(2_000.0, cfg.rate, 0.0))
            .source(events.clone());
        if explicit {
            b = b.clock(Box::new(SimClock::new()));
        }
        b.build().unwrap().run_to_end().unwrap()
    };
    let a = run(false);
    let b = run(true);
    assert_eq!(a.completions, b.completions, "detections diverged");
    assert!(a.latency.stats.count() > 0);
    assert_eq!(a.latency.stats.count(), b.latency.stats.count());
    assert_eq!(
        a.latency.stats.mean().to_bits(),
        b.latency.stats.mean().to_bits(),
        "mean latency diverged"
    );
    assert_eq!(
        a.latency.stats.max().to_bits(),
        b.latency.stats.max().to_bits(),
        "max latency diverged"
    );
    assert_eq!(a.latency.violations, b.latency.violations);
    assert_eq!(a.queue_dropped, b.queue_dropped);
}
