//! Trait-conformance suite for the batch-first `Shedder` API: every
//! `ShedderKind` built through the single `ShedderKind::build` factory
//! must uphold the same contract on *both* `OperatorState` backends
//! (the single-threaded `Operator` and the `ShardedOperator`):
//!
//! * an untrained overload detector never sheds anything,
//! * reported costs are finite and non-negative,
//! * `ShedderKind::None` never sheds even under extreme pressure,
//! * event masks (black-box strategies) always match the batch length
//!   and agree with the reported drop count.

use std::sync::Arc;

use pspice::config::ExperimentConfig;
use pspice::datasets::StockGen;
use pspice::events::{Event, EventStream};
use pspice::model::plane::train_from_operator;
use pspice::model::{ModelBuilder, ModelConfig, ModelKind, TableSet, UtilityModel};
use pspice::operator::{Operator, OperatorState};
use pspice::query::builtin::q1;
use pspice::query::Query;
use pspice::runtime::{FallbackEngine, ShardedOperator};
use pspice::shedding::{OverloadDetector, ShedderKind, ALL_SHEDDER_KINDS};

fn queries() -> Vec<Query> {
    q1(1_500).queries
}

fn cfg() -> ExperimentConfig {
    ExperimentConfig {
        query: "q1".into(),
        window: 1_500,
        ..ExperimentConfig::default()
    }
}

/// A detector trained on a steep linear world: any sizable PM
/// population is over budget, so trained strategies must act.
fn hot_detector() -> OverloadDetector {
    let mut d = OverloadDetector::new(1_000.0, 0.0);
    for n in (0..100).map(|i| i * 50) {
        d.observe_processing(n, 10.0 * n as f64);
        d.observe_shedding(n, n as f64);
    }
    assert!(d.fit());
    d
}

/// Warm a backend with PMs and install utility tables (as an epoch-0
/// [`TableSet`] snapshot — the model-plane install path).
fn warmed(state: &mut dyn OperatorState, warm: &[Event]) {
    // tables from a twin single-threaded operator (the state under
    // test may be sharded; tables are per-query, so they transfer)
    let mut twin = Operator::new(queries());
    for e in warm {
        twin.process_event(e);
    }
    let mut mb = ModelBuilder::new(
        ModelConfig {
            eta: 100,
            max_bins: 64,
            use_tau: true,
        },
        Box::new(FallbackEngine),
    );
    let tables = mb.build(&twin).unwrap();
    for chunk in warm.chunks(512) {
        state.process_batch(chunk, None);
    }
    state.install_table_set(Arc::new(TableSet::initial(tables, Vec::new(), None)));
    assert_eq!(state.table_epoch(), 0);
}

/// Run `kind` over the measurement events on `state` and return
/// (total dropped PMs, total dropped events, total cost).
fn drive(
    kind: ShedderKind,
    detector: &OverloadDetector,
    state: &mut dyn OperatorState,
    measure: &[Event],
    l_q_ns: f64,
) -> (u64, u64, f64) {
    let mut shedder = kind.build(&cfg(), &queries(), detector, 7);
    let (mut pms, mut evs, mut cost) = (0u64, 0u64, 0.0f64);
    for chunk in measure.chunks(64) {
        let before = state.pm_count();
        let rep = shedder.on_batch(chunk, l_q_ns, state);
        assert!(
            rep.cost_ns.is_finite() && rep.cost_ns >= 0.0,
            "{}: cost must be finite and non-negative, got {}",
            kind.name(),
            rep.cost_ns
        );
        assert!(
            rep.dropped_pms <= before as u64,
            "{}: cannot drop more PMs than live",
            kind.name()
        );
        if let Some(mask) = shedder.event_mask() {
            assert_eq!(mask.len(), chunk.len(), "{}: mask length", kind.name());
            let set = mask.count() as u64;
            assert_eq!(set, rep.dropped_events, "{}: mask vs report", kind.name());
        } else {
            assert_eq!(rep.dropped_events, 0, "{}: no mask, no drops", kind.name());
        }
        let mask = shedder.event_mask();
        state.process_batch(chunk, mask);
        pms += rep.dropped_pms;
        evs += rep.dropped_events;
        cost += rep.cost_ns;
    }
    (pms, evs, cost)
}

fn backends(warm: &[Event]) -> Vec<(&'static str, Box<dyn OperatorState>)> {
    let mut single: Box<dyn OperatorState> = Box::new(Operator::new(queries()));
    warmed(single.as_mut(), warm);
    let mut sharded: Box<dyn OperatorState> = Box::new(ShardedOperator::new(queries(), 2));
    warmed(sharded.as_mut(), warm);
    vec![("single", single), ("sharded", sharded)]
}

#[test]
fn untrained_detector_never_sheds_on_any_backend() {
    let trace = StockGen::with_seed(11).take_events(14_000);
    let (warm, measure) = trace.split_at(10_000);
    for (backend, mut state) in backends(warm) {
        for kind in ALL_SHEDDER_KINDS {
            let before = state.pm_count();
            let untrained = OverloadDetector::new(1_000.0, 0.0);
            let (pms, evs, cost) =
                drive(kind, &untrained, state.as_mut(), measure, 1e12);
            assert_eq!(pms, 0, "{backend}/{}: untrained must not drop PMs", kind.name());
            assert_eq!(evs, 0, "{backend}/{}: untrained must not drop events", kind.name());
            assert_eq!(cost, 0.0, "{backend}/{}: untrained costs nothing", kind.name());
            assert!(
                state.pm_count() >= before.min(1),
                "{backend}/{}: processing continued",
                kind.name()
            );
        }
    }
}

#[test]
fn none_never_sheds_even_under_pressure() {
    let trace = StockGen::with_seed(12).take_events(14_000);
    let (warm, measure) = trace.split_at(10_000);
    for (backend, mut state) in backends(warm) {
        let hot = hot_detector();
        let (pms, evs, cost) =
            drive(ShedderKind::None, &hot, state.as_mut(), measure, 1e12);
        assert_eq!((pms, evs), (0, 0), "{backend}: none must never drop");
        assert_eq!(cost, 0.0, "{backend}: none costs nothing");
    }
}

#[test]
fn pspice_sheds_against_the_frequency_only_utility_model() {
    // the model plane's trait-proving backend: pSPICE's decision loop
    // must work unchanged when the tables come from the frequency-only
    // UtilityModel instead of the Markov builder, on both backends
    let trace = StockGen::with_seed(15).take_events(14_000);
    let (warm, measure) = trace.split_at(10_000);
    let mut twin = Operator::new(queries());
    for e in warm {
        twin.process_event(e);
    }
    let mut model = ModelKind::Freq.build(ModelConfig {
        eta: 100,
        max_bins: 64,
        use_tau: true,
    });
    assert_eq!(model.name(), "freq");
    assert!(model.ready(&twin.obs));
    let tables = train_from_operator(model.as_mut(), &twin).unwrap();
    assert_eq!(tables.len(), queries().len());

    let mut single: Box<dyn OperatorState> = Box::new(Operator::new(queries()));
    let mut sharded: Box<dyn OperatorState> = Box::new(ShardedOperator::new(queries(), 2));
    for (backend, state) in [("single", &mut single), ("sharded", &mut sharded)] {
        for chunk in warm.chunks(512) {
            state.process_batch(chunk, None);
        }
        state.install_table_set(Arc::new(TableSet::initial(
            tables.clone(),
            Vec::new(),
            None,
        )));
        assert!(state.pm_count() > 10, "{backend}: scenario needs PMs");
        let hot = hot_detector();
        let (pms, evs, cost) =
            drive(ShedderKind::PSpice, &hot, state.as_mut(), measure, 1e9);
        assert!(pms > 0, "{backend}: pSPICE must shed on freq tables");
        assert_eq!(evs, 0, "{backend}: white-box drops no events");
        assert!(cost > 0.0, "{backend}: shedding costs time");
    }
}

#[test]
fn trained_strategies_act_identically_shaped_on_both_backends() {
    let trace = StockGen::with_seed(13).take_events(14_000);
    let (warm, measure) = trace.split_at(10_000);
    for (backend, mut state) in backends(warm) {
        assert!(state.pm_count() > 10, "{backend}: scenario needs PMs");
        for kind in ALL_SHEDDER_KINDS {
            if kind == ShedderKind::None {
                continue;
            }
            let hot = hot_detector();
            let (pms, evs, cost) =
                drive(kind, &hot, state.as_mut(), measure, 1e9);
            match kind {
                ShedderKind::PSpice | ShedderKind::PSpiceMinus | ShedderKind::PmBaseline => {
                    assert!(pms > 0, "{backend}/{}: PM strategy must drop PMs", kind.name());
                    assert_eq!(evs, 0, "{backend}/{}: PM strategy drops no events", kind.name());
                }
                ShedderKind::EventBaseline => {
                    assert!(evs > 0, "{backend}/{}: E-BL must drop events", kind.name());
                    assert_eq!(pms, 0, "{backend}/{}: E-BL drops no PMs", kind.name());
                }
                ShedderKind::None => unreachable!(),
            }
            assert!(cost > 0.0, "{backend}/{}: acting costs time", kind.name());
        }
    }
}
