//! §III-D integration: drift-triggered model retraining end-to-end —
//! single-threaded through `run_experiment`, and sharded through the
//! model plane (`ModelController` harvest → train → `TableSet`
//! broadcast), including victim-selection equivalence against a
//! single-threaded reference.

use std::sync::Arc;

use pspice::config::ExperimentConfig;
use pspice::datasets::{mixed_queries, mixed_trace, DatasetKind};
use pspice::harness::run_experiment;
use pspice::model::{DriftDetector, ModelConfig, ModelController, ModelKind, TableSet};
use pspice::operator::{ObservationHub, Operator, OperatorState};
use pspice::query::builtin::q4;
use pspice::runtime::sharded::sort_completions;
use pspice::runtime::ShardedOperator;
use pspice::shedding::ShedderKind;

fn base() -> ExperimentConfig {
    ExperimentConfig {
        query: "q4".into(),
        window: 2_000,
        pattern_n: 4,
        slide: 250,
        dataset: DatasetKind::Bus,
        seed: 3,
        warmup: 20_000,
        events: 25_000,
        rate: 1.4,
        lb_ms: 0.05,
        shedder: ShedderKind::PSpice,
        model: ModelKind::Markov,
        weights: Vec::new(),
        cost_factors: Vec::new(),
        retrain_every: 0,
        drift_threshold: 0.01,
        shards: 1,
        batch: 256,
        ..ExperimentConfig::default()
    }
}

#[test]
fn retraining_disabled_by_default() {
    let r = run_experiment(&base()).unwrap();
    assert_eq!(r.retrains, 0);
}

#[test]
fn stationary_stream_rarely_retrains() {
    // the bus stream is stationary: with a sane threshold the detector
    // should not thrash
    let mut cfg = base();
    cfg.retrain_every = 5_000;
    cfg.drift_threshold = 0.02;
    let r = run_experiment(&cfg).unwrap();
    assert!(r.retrains <= 1, "stationary stream retrained {}x", r.retrains);
    // and the run stays healthy
    assert_eq!(r.false_positives, 0);
    assert!(r.latency.violation_rate() < 0.05);
}

#[test]
fn tight_threshold_forces_retrains_and_stays_correct() {
    let mut cfg = base();
    cfg.retrain_every = 2_000;
    cfg.drift_threshold = 1e-9; // everything counts as drift
    let r = run_experiment(&cfg).unwrap();
    assert!(r.retrains >= 3, "retrains={}", r.retrains);
    // retrained tables keep the shedder functional
    assert_eq!(r.false_positives, 0);
    assert!((0.0..=100.0).contains(&r.fn_percent));
    assert!(r.latency.violation_rate() < 0.05);
}

#[test]
fn drift_detector_fires_on_distribution_shift() {
    // learn a model on one bus world, then observe a very different one
    // (different seed => different hotspot stops & routes): the
    // transition statistics must drift past a tight threshold
    let mut op1 = Operator::new(q4(4, 2_000, 250).queries);
    let mut g1 = pspice::datasets::BusGen::with_seed(1);
    use pspice::events::EventStream;
    for _ in 0..40_000 {
        op1.process_event(&g1.next_event().unwrap());
    }
    let det = DriftDetector::snapshot(&op1.obs, 1e-5);

    let mut shifted = Operator::new(q4(4, 2_000, 250).queries);
    let mut cfg = pspice::datasets::bus::BusConfig::default();
    cfg.incident_p *= 8.0; // much stormier city
    let mut g2 = pspice::datasets::BusGen::new(99, cfg);
    for _ in 0..40_000 {
        shifted.process_event(&g2.next_event().unwrap());
    }
    let (mse, drifted) = det.check(&shifted.obs);
    assert!(drifted, "mse={mse} must exceed 1e-5 after the shift");

    // sanity: same distribution does NOT drift at a loose threshold
    let mut op_same = Operator::new(q4(4, 2_000, 250).queries);
    let mut g3 = pspice::datasets::BusGen::with_seed(1);
    for _ in 0..40_000 {
        op_same.process_event(&g3.next_event().unwrap());
    }
    let det_loose = DriftDetector::snapshot(&op1.obs, 0.005);
    let (mse_same, drifted_same) = det_loose.check(&op_same.obs);
    assert!(!drifted_same, "identical stream drifted: mse={mse_same}");
    let _ = ObservationHub::new(&[2]);
}

/// Drive one backend through the mixed workload with a tightly-wound
/// `ModelController`: warm-up, drift baseline, a retrain checkpoint,
/// then a shed round.  Returns everything retrain equivalence is
/// judged on: sorted completions, dropped PMs, the survivor population
/// coordinates, the final epoch, and how many retrains fired.
#[allow(clippy::type_complexity)]
fn drive_retraining(
    state: &mut dyn OperatorState,
    warm: &[pspice::events::Event],
    tail: &[pspice::events::Event],
    batch: usize,
    rho: usize,
) -> (
    Vec<pspice::operator::ComplexEvent>,
    usize,
    Vec<(usize, u64, u64, u32)>,
    u64,
    u32,
) {
    let n = 8; // mixed_queries is eight queries
    let initial = Arc::new(TableSet::initial(Vec::new(), vec![1.0; n], None));
    let mut ctl = ModelController::new(
        ModelKind::Markov.build(ModelConfig {
            eta: 100,
            max_bins: 64,
            use_tau: true,
        }),
        1e-12, // everything counts as drift
        vec![1.0; n],
        initial,
    );
    ctl.install_initial(state);
    for chunk in warm.chunks(batch) {
        state.process_batch(chunk, None);
    }
    ctl.begin(state);

    let mut ces = Vec::new();
    let mut dropped = 0usize;
    for (i, chunk) in tail.chunks(batch).enumerate() {
        ces.extend(state.process_batch(chunk, None).completions);
        if i == 4 {
            // harvest → drift (tight threshold) → train → publish
            assert!(ctl.check_and_retrain(state).unwrap(), "must retrain");
        }
        if i == 8 {
            // shed from the retrained tables
            dropped += state.shed_lowest(rho).dropped;
        }
    }
    sort_completions(&mut ces);

    let mut refs = Vec::new();
    state.pm_refs(&mut refs);
    let mut coords: Vec<(usize, u64, u64, u32)> = refs
        .iter()
        .map(|r| (r.query, r.open_seq, r.key_bits, r.state))
        .collect();
    coords.sort_unstable();
    (ces, dropped, coords, state.table_epoch(), ctl.retrains())
}

#[test]
fn sharded_retraining_matches_single_threaded_reference() {
    // the acceptance test for the model plane: at shards ∈ {2, 4}, the
    // broadcast TableSet epoch reaches the coordinator, and shedding
    // from the retrained tables selects the exact same victims (hence
    // the same completions and survivors) as a single-threaded run
    // driven with identical batches and the same controller schedule
    let trace = mixed_trace(40_000, 5);
    let (warm, tail) = trace.split_at(24_000);
    let batch = 512;
    let rho = 150;

    let mut single = Operator::new(mixed_queries(2_000));
    let reference = drive_retraining(&mut single, warm, tail, batch, rho);
    assert!(!reference.0.is_empty(), "scenario must detect something");
    assert!(reference.1 > 0, "shed round must drop PMs");
    assert_eq!(reference.3, 1, "one retrain => epoch 1");
    assert_eq!(reference.4, 1);

    for shards in [2usize, 4] {
        let mut sop = ShardedOperator::new(mixed_queries(2_000), shards);
        let run = drive_retraining(&mut sop, warm, tail, batch, rho);
        assert_eq!(
            run.0, reference.0,
            "shards={shards}: completions diverged from the reference"
        );
        assert_eq!(run.1, reference.1, "shards={shards}: drop counts diverged");
        assert_eq!(run.2, reference.2, "shards={shards}: survivors diverged");
        assert_eq!(run.3, 1, "shards={shards}: coordinator epoch");
        assert_eq!(run.4, 1, "shards={shards}: retrain count");
        // the broadcast reached every worker, not just the coordinator
        assert_eq!(sop.worker_epochs(), vec![1; shards]);
    }
}

#[test]
fn pipeline_retrains_at_shards_gt_1() {
    // the end-to-end acceptance: PipelineBuilder::retrain no longer
    // rejects shards > 1, and the sharded measurement phase actually
    // rebuilds the model under a tight drift threshold
    let mut cfg = base();
    cfg.query = "q1+q2".into(); // four queries -> a real 2-shard split
    cfg.dataset = DatasetKind::Stock;
    cfg.window = 2_000;
    cfg.shards = 2;
    cfg.batch = 250;
    cfg.retrain_every = 5_000;
    cfg.drift_threshold = 1e-9;
    let r = run_experiment(&cfg).unwrap();
    assert_eq!(r.shards, 2);
    assert!(r.retrains >= 1, "retrains={}", r.retrains);
    assert_eq!(r.false_positives, 0);
    assert!((0.0..=100.0).contains(&r.fn_percent));
}
