//! §III-D integration: drift-triggered model retraining end-to-end.
//!
//! The drift detector compares a freshly learned transition matrix
//! against the one the live model was built from; when the input
//! distribution shifts, the model must be rebuilt.

use pspice::config::ExperimentConfig;
use pspice::datasets::DatasetKind;
use pspice::harness::run_experiment;
use pspice::model::DriftDetector;
use pspice::operator::{ObservationHub, Operator};
use pspice::query::builtin::q4;
use pspice::shedding::ShedderKind;

fn base() -> ExperimentConfig {
    ExperimentConfig {
        query: "q4".into(),
        window: 2_000,
        pattern_n: 4,
        slide: 250,
        dataset: DatasetKind::Bus,
        seed: 3,
        warmup: 20_000,
        events: 25_000,
        rate: 1.4,
        lb_ms: 0.05,
        shedder: ShedderKind::PSpice,
        weights: Vec::new(),
        cost_factors: Vec::new(),
        retrain_every: 0,
        drift_threshold: 0.01,
        shards: 1,
        batch: 256,
    }
}

#[test]
fn retraining_disabled_by_default() {
    let r = run_experiment(&base()).unwrap();
    assert_eq!(r.retrains, 0);
}

#[test]
fn stationary_stream_rarely_retrains() {
    // the bus stream is stationary: with a sane threshold the detector
    // should not thrash
    let mut cfg = base();
    cfg.retrain_every = 5_000;
    cfg.drift_threshold = 0.02;
    let r = run_experiment(&cfg).unwrap();
    assert!(r.retrains <= 1, "stationary stream retrained {}x", r.retrains);
    // and the run stays healthy
    assert_eq!(r.false_positives, 0);
    assert!(r.latency.violation_rate() < 0.05);
}

#[test]
fn tight_threshold_forces_retrains_and_stays_correct() {
    let mut cfg = base();
    cfg.retrain_every = 2_000;
    cfg.drift_threshold = 1e-9; // everything counts as drift
    let r = run_experiment(&cfg).unwrap();
    assert!(r.retrains >= 3, "retrains={}", r.retrains);
    // retrained tables keep the shedder functional
    assert_eq!(r.false_positives, 0);
    assert!((0.0..=100.0).contains(&r.fn_percent));
    assert!(r.latency.violation_rate() < 0.05);
}

#[test]
fn drift_detector_fires_on_distribution_shift() {
    // learn a model on one bus world, then observe a very different one
    // (different seed => different hotspot stops & routes): the
    // transition statistics must drift past a tight threshold
    let mut op1 = Operator::new(q4(4, 2_000, 250).queries);
    let mut g1 = pspice::datasets::BusGen::with_seed(1);
    use pspice::events::EventStream;
    for _ in 0..40_000 {
        op1.process_event(&g1.next_event().unwrap());
    }
    let det = DriftDetector::snapshot(&op1.obs, 1e-5);

    let mut shifted = Operator::new(q4(4, 2_000, 250).queries);
    let mut cfg = pspice::datasets::bus::BusConfig::default();
    cfg.incident_p *= 8.0; // much stormier city
    let mut g2 = pspice::datasets::BusGen::new(99, cfg);
    for _ in 0..40_000 {
        shifted.process_event(&g2.next_event().unwrap());
    }
    let (mse, drifted) = det.check(&shifted.obs);
    assert!(drifted, "mse={mse} must exceed 1e-5 after the shift");

    // sanity: same distribution does NOT drift at a loose threshold
    let mut op_same = Operator::new(q4(4, 2_000, 250).queries);
    let mut g3 = pspice::datasets::BusGen::with_seed(1);
    for _ in 0..40_000 {
        op_same.process_event(&g3.next_event().unwrap());
    }
    let det_loose = DriftDetector::snapshot(&op1.obs, 0.005);
    let (mse_same, drifted_same) = det_loose.check(&op_same.obs);
    assert!(!drifted_same, "identical stream drifted: mse={mse_same}");
    let _ = ObservationHub::new(&[2]);
}
