//! Shed-equivalence property suite: the cell-based `shed_lowest`
//! (ranking `(query, window, state)` cells off the incrementally
//! maintained per-window state counts) must reproduce the *reference*
//! per-PM selection — sort every live PM by the engine's documented
//! deterministic order `(utility, query, open_seq, state, window
//! position)` and drop the first ρ — exactly: same drop count, same
//! victim utility multiset, and bit-for-bit identical completions
//! downstream.  The same must hold between the single-threaded
//! `Operator` and the `ShardedOperator`'s k-way cell merge.


use pspice::datasets::{mixed_queries, mixed_trace, BusGen, StockGen};
use pspice::events::{DropMask, Event, EventStream};
use pspice::model::UtilityTable;
use pspice::nfa::CompiledQuery;
use pspice::operator::{Operator, OperatorState};
use pspice::query::builtin::{q1, q4};
use pspice::query::Query;
use pspice::runtime::sharded::sort_completions;
use pspice::runtime::ShardedOperator;
use pspice::testing::{forall, Gen};

/// Deterministic synthetic utility tables (one per query) with varied
/// values — model building is irrelevant to selection semantics, so the
/// properties quantify over arbitrary tables instead of trained ones.
fn synthetic_tables(queries: &[Query], g: &mut Gen) -> Vec<UtilityTable> {
    queries
        .iter()
        .map(|q| {
            let m = CompiledQuery::compile(q.clone()).m;
            let nbins = g.usize(3, 10);
            let bs = g.usize(5, 50) as u64;
            let rows = (0..nbins)
                .map(|_| (0..m).map(|_| g.f64(0.0, 2.0)).collect())
                .collect();
            UtilityTable { m, bs, rows }
        })
        .collect()
}

/// The reference (pre-cell-index) per-PM selection: enumerate every PM,
/// key it by the documented deterministic order, drop the first ρ by
/// id.  Returns how many were dropped.
fn reference_shed_lowest(op: &mut Operator, tables: &[UtilityTable], rho: usize) -> usize {
    let mut refs = Vec::new();
    op.pm_refs(&mut refs);
    let n = refs.len();
    if n == 0 || rho == 0 {
        return 0;
    }
    let rho = rho.min(n);
    // pm_refs enumerates (query, window, position) in order, so the
    // index is the position tie-break
    let mut keyed: Vec<(f64, usize, u64, u32, usize, u64)> = refs
        .iter()
        .enumerate()
        .map(|(idx, r)| {
            (
                tables
                    .get(r.query)
                    .map_or(0.0, |t| t.lookup(r.state, r.remaining)),
                r.query,
                r.open_seq,
                r.state,
                idx,
                r.pm_id,
            )
        })
        .collect();
    keyed.sort_unstable_by(|a, b| {
        a.0
            .total_cmp(&b.0)
            .then_with(|| a.1.cmp(&b.1))
            .then_with(|| a.2.cmp(&b.2))
            .then_with(|| a.3.cmp(&b.3))
            .then_with(|| a.4.cmp(&b.4))
    });
    let mut ids: Vec<u64> = keyed[..rho].iter().map(|k| k.5).collect();
    ids.sort_unstable();
    op.drop_pms(&ids)
}

/// Sharding-invariant coordinates of the whole live population, sorted
/// (pm ids differ across backends, so they are excluded).
fn population(op: &dyn pspice::operator::OperatorState) -> Vec<(usize, u64, u64, u32)> {
    let mut refs = Vec::new();
    op.pm_refs(&mut refs);
    let mut coords: Vec<(usize, u64, u64, u32)> = refs
        .iter()
        .map(|r| (r.query, r.open_seq, r.key_bits, r.state))
        .collect();
    coords.sort_unstable();
    coords
}

/// Random (queries, warm trace, tail trace) scenario over both stream
/// families.
fn scenario(g: &mut Gen) -> (Vec<Query>, Vec<Event>, Vec<Event>) {
    let (queries, events) = if g.bool(0.5) {
        let n = g.usize(3, 5);
        let ws = g.usize(1_000, 3_000) as u64;
        let slide = g.usize(100, 500) as u64;
        let mut gen = BusGen::with_seed(g.usize(0, 1 << 20) as u64);
        (q4(n, ws, slide).queries, gen.take_events(g.usize(5_000, 9_000)))
    } else {
        let ws = g.usize(800, 2_500) as u64;
        let mut gen = StockGen::with_seed(g.usize(0, 1 << 20) as u64);
        (q1(ws).queries, gen.take_events(g.usize(5_000, 9_000)))
    };
    let split = events.len() * 2 / 3;
    let tail = events[split..].to_vec();
    let mut warm = events;
    warm.truncate(split);
    (queries, warm, tail)
}

#[test]
fn prop_cell_shed_matches_reference_per_pm_selection() {
    forall(8, 4242, |g| {
        let (queries, warm, tail) = scenario(g);
        let tables = synthetic_tables(&queries, g);
        let mut base = Operator::new(queries);
        for e in &warm {
            base.process_event(e);
        }
        let before = base.pm_count();
        if before == 0 {
            return; // vacuous case
        }
        let rho = g.usize(1, before + before / 4 + 1); // overdraw included

        let mut cell = base.clone();
        cell.install_tables(&tables);
        let out = cell.shed_lowest(rho);
        assert_eq!(out.scanned, before);
        assert_eq!(out.dropped, rho.min(before));

        let mut reference = base;
        let dropped = reference_shed_lowest(&mut reference, &tables, rho);
        assert_eq!(out.dropped, dropped, "drop counts diverged");

        // identical victim sets ⇒ identical survivor populations (this
        // also implies the dropped utility multisets are identical)
        assert_eq!(
            population(&cell),
            population(&reference),
            "survivors diverged (rho={rho}, n={before})"
        );

        // ... and bit-for-bit identical completions downstream
        let mut ces_cell = Vec::new();
        let mut ces_ref = Vec::new();
        for e in &tail {
            ces_cell.extend(cell.process_event(e).completions);
            ces_ref.extend(reference.process_event(e).completions);
        }
        assert_eq!(ces_cell, ces_ref, "downstream completions diverged");
        assert_eq!(cell.pm_count(), reference.pm_count());
    });
}

#[test]
fn prop_sharded_cell_merge_matches_single_operator() {
    forall(6, 9191, |g| {
        // q1's two queries so multi-shard splits actually distribute
        let ws = g.usize(800, 2_500) as u64;
        let queries = q1(ws).queries;
        let mut gen = StockGen::with_seed(g.usize(0, 1 << 20) as u64);
        let events = gen.take_events(g.usize(6_000, 10_000));
        let split = events.len() * 2 / 3;
        let tables = synthetic_tables(&queries, g);
        let shards = g.usize(2, 3);
        let batch = g.usize(64, 700);

        let mut single = Operator::new(queries.clone());
        for e in &events[..split] {
            single.process_event(e);
        }
        let before = single.pm_count();
        if before == 0 {
            return;
        }
        single.install_tables(&tables);

        let mut sharded = ShardedOperator::new(queries, shards);
        for chunk in events[..split].chunks(batch) {
            sharded.process_batch(chunk);
        }
        sharded.set_tables(&tables);
        assert_eq!(sharded.pm_count(), before);

        let rho = g.usize(1, before);
        let a = single.shed_lowest(rho);
        let b = sharded.shed_lowest(rho);
        assert_eq!(a.dropped, b.dropped, "drop counts diverged");
        assert_eq!(a.scanned, b.scanned);
        assert_eq!(
            population(&single),
            population(&sharded),
            "victim sets diverged (shards={shards}, rho={rho})"
        );

        // downstream completions stay identical too
        let mut ces_single = Vec::new();
        for e in &events[split..] {
            ces_single.extend(single.process_event(e).completions);
        }
        sort_completions(&mut ces_single);
        let mut ces_sharded = Vec::new();
        for chunk in events[split..].chunks(batch) {
            ces_sharded.extend(sharded.process_batch(chunk).completions);
        }
        sort_completions(&mut ces_sharded);
        assert_eq!(ces_single, ces_sharded, "downstream completions diverged");
        assert_eq!(single.pm_count(), sharded.pm_count());
    });
}

/// One run of the pooled/routed measurement loop: batches (some with a
/// pooled drop mask) interleaved with fixed-ρ shed rounds.  Returns
/// everything victim-order equivalence is judged on: sorted
/// completions, the (dropped, pm_count) trail of every shed round, and
/// the final population coordinates.
#[allow(clippy::type_complexity)]
fn drive_masked_shedding(
    state: &mut dyn OperatorState,
    trace: &[Event],
    masks: &[Option<DropMask>],
    batch: usize,
    rho: usize,
) -> (
    Vec<pspice::operator::ComplexEvent>,
    Vec<(usize, usize)>,
    Vec<(usize, u64, u64, u32)>,
) {
    let mut ces = Vec::new();
    let mut sheds = Vec::new();
    for (i, chunk) in trace.chunks(batch).enumerate() {
        let mask = masks[i].as_ref();
        ces.extend(state.process_batch(chunk, mask).completions);
        if i % 4 == 3 {
            let out = state.shed_lowest(rho);
            sheds.push((out.dropped, state.pm_count()));
        }
    }
    sort_completions(&mut ces);
    (ces, sheds, population(state))
}

#[test]
fn prop_pooled_routed_plane_is_equivalent_to_pr3_dispatch() {
    // The PR 4 acceptance property: the pooled batch/mask plane with
    // type-routed dispatch must produce identical completions, drops
    // and victim order to (a) the same shard count with routing off
    // (the PR 3 matching behavior), (b) other shard counts, and (c)
    // the single-threaded operator — on a mixed multi-family workload
    // where every shard hosts queries that skim a large share of the
    // stream.  Shed rounds use synthetic utility tables so victim
    // order is exercised, and pooled drop masks cover the black-box
    // path.
    forall(4, 2024, |g| {
        let queries = mixed_queries(g.usize(1_200, 2_500) as u64);
        let trace = mixed_trace(g.usize(9_000, 15_000), g.usize(0, 1 << 16) as u64);
        let batch = g.usize(128, 900);
        let rho = g.usize(8, 48);
        let tables = synthetic_tables(&queries, g);
        // one shared mask schedule: every 3rd batch sheds a random
        // ~10% of its events through the pooled mask plane
        let n_chunks = trace.len().div_ceil(batch);
        let masks: Vec<Option<DropMask>> = (0..n_chunks)
            .map(|i| {
                if i % 3 != 1 {
                    return None;
                }
                let len = batch.min(trace.len() - i * batch);
                let mut m = DropMask::default();
                m.reset(len);
                for j in 0..len {
                    if g.bool(0.1) {
                        m.mark(j);
                    }
                }
                Some(m)
            })
            .collect();

        let mut runs = Vec::new();
        for &shards in &[1usize, 2, 4] {
            for &routing in &[true, false] {
                let mut sop = ShardedOperator::new(queries.clone(), shards);
                sop.set_type_routing(routing);
                sop.set_tables(&tables);
                runs.push((
                    format!("sharded(shards={shards}, routing={routing})"),
                    drive_masked_shedding(&mut sop, &trace, &masks, batch, rho),
                ));
            }
        }
        for &routing in &[true, false] {
            let mut op = Operator::new(queries.clone());
            op.set_type_routing(routing);
            op.install_tables(&tables);
            runs.push((
                format!("single(routing={routing})"),
                drive_masked_shedding(&mut op, &trace, &masks, batch, rho),
            ));
        }
        let (ref_name, reference) = &runs[0];
        assert!(
            !reference.1.is_empty(),
            "scenario must include shed rounds"
        );
        for (name, run) in &runs[1..] {
            assert_eq!(run.0, reference.0, "{name} completions != {ref_name}");
            assert_eq!(run.1, reference.1, "{name} shed trail != {ref_name}");
            assert_eq!(run.2, reference.2, "{name} survivors != {ref_name}");
        }
    });
}
