//! Real-time ingestion plane, end to end: synthetic overload must be
//! survivable *with* shedding and damaging *without* it, the bounded
//! ingest queue must honor its overflow policy's accounting, and the
//! wall-clock plane must run the same loop against real time.
//!
//! Every virtual-mode test here is deterministic (seeded generators on
//! a `SimClock` timeline); assertions on the overload runs are
//! comparative (shedding vs. none on the identical arrival schedule)
//! rather than absolute thresholds, so they hold on any host.

use pspice::config::ExperimentConfig;
use pspice::datasets::DatasetKind;
use pspice::harness::run_realtime_experiment;
use pspice::ingest::{OverflowPolicy, SourceKind};
use pspice::shedding::ShedderKind;

fn rt_cfg() -> ExperimentConfig {
    ExperimentConfig {
        query: "q4".into(),
        window: 2_000,
        pattern_n: 4,
        slide: 250,
        dataset: DatasetKind::Bus,
        seed: 3,
        events: 10_000,
        warmup: 12_000,
        rate: 1.4,
        lb_ms: 0.05,
        shedder: ShedderKind::PSpice,
        ..ExperimentConfig::default()
    }
}

#[test]
fn shedding_beats_no_shedding_on_the_same_bursts() {
    // identical burst schedule (2.8x capacity peaks, mean load > 1):
    // without shedding the backlog compounds and the tail blows the
    // bound; with pSPICE the bound holds far better
    let mut cfg = rt_cfg();
    cfg.source = SourceKind::Burst;
    let with = run_realtime_experiment(&cfg, None, false).unwrap();

    cfg.shedder = ShedderKind::None;
    let without = run_realtime_experiment(&cfg, None, false).unwrap();

    assert!(with.dropped_pms > 0, "bursts must force shedding");
    assert_eq!(without.dropped_pms, 0, "`none` must never shed");
    let lb_ns = without.lb_ms * 1e6;
    assert!(
        without.latency.p95_ns() > lb_ns,
        "unshed bursts must violate the bound (p95 = {} ns)",
        without.latency.p95_ns()
    );
    assert!(
        with.latency.p95_ns() < without.latency.p95_ns(),
        "shedding must improve the tail: {} vs {} ns",
        with.latency.p95_ns(),
        without.latency.p95_ns()
    );
    assert!(
        with.latency.violation_rate() < without.latency.violation_rate(),
        "shedding must cut the violation rate: {} vs {}",
        with.latency.violation_rate(),
        without.latency.violation_rate()
    );
}

#[test]
fn block_policy_loses_nothing_drop_oldest_accounts_for_losses() {
    // a flash crowd against a tiny queue with shedding off: `block`
    // backpressures the source and processes every event; `drop-oldest`
    // evicts, and every eviction shows up in the accounting
    let mut cfg = rt_cfg();
    cfg.source = SourceKind::FlashCrowd;
    cfg.shedder = ShedderKind::None;
    cfg.ingest_capacity = 256;

    cfg.ingest_policy = OverflowPolicy::Block;
    let blocked = run_realtime_experiment(&cfg, None, false).unwrap();
    assert_eq!(blocked.queue_dropped, 0, "block must never lose events");
    assert_eq!(
        blocked.events_processed(),
        10_000,
        "backpressure defers, it does not discard"
    );

    cfg.ingest_policy = OverflowPolicy::DropOldest;
    let dropping = run_realtime_experiment(&cfg, None, false).unwrap();
    assert!(
        dropping.queue_dropped > 0,
        "a flash crowd must overflow a 256-event queue"
    );
    assert_eq!(
        dropping.events_processed() + dropping.queue_dropped,
        10_000,
        "every generated event is either processed or counted dropped"
    );
}

#[test]
fn wall_clock_run_terminates_and_processes_events() {
    // the wall plane: real time underneath, modeled service costs as a
    // virtual offset, scheduled gaps fast-forwarded — so this finishes
    // in milliseconds of real time while modeling the same overload
    let mut cfg = rt_cfg();
    cfg.source = SourceKind::Oscillate;
    cfg.events = 2_000;
    cfg.duration_ms = 500.0;
    let res = run_realtime_experiment(&cfg, None, true).unwrap();
    assert!(res.wall, "result must be stamped as a wall-clock run");
    assert_eq!(res.source, "oscillate");
    assert!(res.events_processed() > 0, "wall run must process events");
    assert!(res.events_processed() <= 2_000);
    assert!(res.real_elapsed_secs >= 0.0);
}
