//! Property suites over the crate's core invariants (DESIGN.md §7),
//! using the seeded mini property framework in `pspice::testing`.


use pspice::linalg::markov::{absorbing_normalize, build_tables, compose_bin};
use pspice::linalg::{fit_latency_model, Mat};
use pspice::model::UtilityTable;
use pspice::operator::Operator;
use pspice::query::builtin;
use pspice::shedding::OverloadDetector;
use pspice::testing::{forall, Gen};
use pspice::util::Rng;
use pspice::windows::QueryWindows;

// ---------------------------------------------------------------- markov

#[test]
fn prop_completion_equals_matrix_power() {
    // paper Eq. 3: c_j(i) == T^j (i, m-1)
    forall(40, 101, |g| {
        let m = g.usize(2, 10);
        let t = g.stochastic_matrix(m);
        let r = vec![1.0; m];
        let nbins = g.usize(1, 30);
        let tables = build_tables(&t, &r, nbins);
        let j = g.usize(1, nbins);
        let p = t.pow(j as u64);
        for i in 0..m {
            assert!(
                (tables.completion[j - 1][i] - p[(i, m - 1)]).abs() < 1e-9,
                "m={m} j={j} i={i}"
            );
        }
    });
}

#[test]
fn prop_completion_monotone_and_bounded() {
    forall(40, 102, |g| {
        let m = g.usize(2, 12);
        let t = g.stochastic_matrix(m);
        let tables = build_tables(&t, &vec![0.5; m], 40);
        for j in 0..40 {
            for i in 0..m {
                let c = tables.completion[j][i];
                assert!((-1e-12..=1.0 + 1e-9).contains(&c));
                if j > 0 {
                    assert!(c + 1e-9 >= tables.completion[j - 1][i]);
                }
            }
        }
    });
}

#[test]
fn prop_compose_bin_chapman_kolmogorov() {
    // one composed step == bs raw steps, for random chains and bins
    forall(30, 103, |g| {
        let m = g.usize(2, 8);
        let t = g.stochastic_matrix(m);
        let mut r: Vec<f64> = (0..m).map(|_| g.f64(0.0, 3.0)).collect();
        r[m - 1] = 0.0;
        let bs = g.usize(1, 40) as u64;
        let (tb, rb) = compose_bin(&t, &r, bs);
        assert!(tb.is_row_stochastic(1e-9));
        let direct = build_tables(&t, &r, bs as usize);
        let binned = build_tables(&tb, &rb, 1);
        for i in 0..m {
            assert!(
                (binned.completion[0][i] - direct.completion[bs as usize - 1][i]).abs()
                    < 1e-8
            );
            assert!(
                (binned.remaining_time[0][i]
                    - direct.remaining_time[bs as usize - 1][i])
                    .abs()
                    < 1e-6
            );
        }
    });
}

#[test]
fn prop_learned_matrices_are_stochastic() {
    forall(25, 104, |g| {
        let m = g.usize(2, 9);
        let mut t = Mat::zeros(m, m);
        // random raw counts, some rows empty
        for i in 0..m {
            if g.bool(0.8) {
                for j in 0..m {
                    t[(i, j)] = g.usize(0, 50) as f64;
                }
            }
        }
        absorbing_normalize(&mut t);
        assert!(t.is_row_stochastic(1e-9));
        assert_eq!(t[(m - 1, m - 1)], 1.0);
    });
}

// ---------------------------------------------------------------- utility

#[test]
fn prop_utility_lookup_matches_rows_at_bin_boundaries() {
    forall(25, 105, |g| {
        let m = g.usize(2, 8);
        let t = g.stochastic_matrix(m);
        let tables = build_tables(&t, &vec![1.0; m], 32);
        let bs = g.usize(1, 100) as u64;
        let ut = UtilityTable::from_tables(&tables, 1.0, bs, true);
        let j = g.usize(0, 31);
        let s = g.usize(0, m - 1) as u32;
        let looked = ut.lookup(s, (j as u64 + 1) * bs);
        assert!(
            (looked - ut.rows[j][s as usize]).abs() < 1e-9,
            "bin boundary lookup must be exact"
        );
    });
}

#[test]
fn prop_utility_nonnegative_finite() {
    forall(25, 106, |g| {
        let m = g.usize(2, 8);
        let t = g.stochastic_matrix(m);
        let mut r: Vec<f64> = (0..m).map(|_| g.f64(0.0, 10.0)).collect();
        r[m - 1] = 0.0;
        let tables = build_tables(&t, &r, 16);
        let ut = UtilityTable::from_tables(&tables, g.f64(0.1, 5.0), 10, g.bool(0.5));
        for row in &ut.rows {
            for &u in row {
                assert!(u.is_finite() && u >= 0.0);
            }
        }
    });
}

// ---------------------------------------------------------------- detector

#[test]
fn prop_detector_rho_restores_bound() {
    // for any linear latency world, the returned rho brings the
    // predicted latency back under LB (Alg. 1 invariant, item 8)
    forall(30, 107, |g| {
        let a = g.f64(0.0, 500.0);
        let b = g.f64(0.5, 20.0);
        let lb = g.f64(5_000.0, 100_000.0);
        let mut d = OverloadDetector::new(lb, 0.0);
        for n in (0..100).map(|i| i * 20) {
            d.observe_processing(n, a + b * n as f64);
            d.observe_shedding(n, 0.1 * b * n as f64);
        }
        assert!(d.fit());
        let n_pm = g.usize(10, 20_000);
        let l_q = g.f64(0.0, lb * 0.5);
        if let Some(rho) = d.check(l_q, n_pm) {
            assert!(rho <= n_pm);
            let kept = n_pm - rho;
            if kept > 0 {
                // interior solution: the bound is restored
                let after = l_q + d.predict_lp(kept) + d.predict_ls(n_pm);
                // allow the regression + ceil slack of one PM's latency
                assert!(
                    after <= lb + b * 2.0 + 1.0,
                    "after={after} lb={lb} rho={rho} n={n_pm}"
                );
            } else {
                // infeasible bound (queueing/shedding alone exceed LB):
                // the detector must have asked for maximum effort
                assert_eq!(rho, n_pm);
                assert!(l_q + d.predict_lp(0) + d.predict_ls(n_pm) + 1.0 >= lb);
            }
        }
    });
}

#[test]
fn prop_regression_inverse_is_monotone() {
    forall(20, 108, |g| {
        let xs: Vec<f64> = (0..80).map(|i| i as f64 * g.f64(1.0, 30.0)).collect();
        let a = g.f64(0.0, 100.0);
        let b = g.f64(0.01, 5.0);
        let c = g.f64(0.0, 0.01);
        let ys: Vec<f64> = xs.iter().map(|&n| a + b * n + c * n * n).collect();
        let m = fit_latency_model(&xs, &ys).expect("fit");
        let l1 = g.f64(a, a + 1000.0);
        let l2 = l1 + g.f64(1.0, 1000.0);
        assert!(m.inverse(l1) <= m.inverse(l2) + 1e-6);
    });
}

// ---------------------------------------------------------------- operator

fn random_bus_operator(g: &mut Gen) -> (Operator, Rng) {
    use pspice::events::EventStream;
    let n = g.usize(2, 6);
    let ws = g.usize(500, 4_000) as u64;
    let slide = g.usize(100, 800) as u64;
    let mut op = Operator::new(builtin::q4(n, ws, slide).queries);
    let mut gen = pspice::datasets::BusGen::with_seed(g.usize(0, 1 << 30) as u64);
    let events = g.usize(2_000, 15_000);
    for _ in 0..events {
        op.process_event(&gen.next_event().unwrap());
    }
    (op, g.rng())
}

#[test]
fn prop_pm_count_cache_consistent() {
    forall(10, 109, |g| {
        let (op, _) = random_bus_operator(g);
        let direct: usize = op.wins.iter().map(|q| q.pm_count()).sum();
        assert_eq!(direct, op.pm_count());
        let mut refs = Vec::new();
        op.pm_refs(&mut refs);
        assert_eq!(refs.len(), op.pm_count());
    });
}

#[test]
fn prop_windows_respect_extent() {
    forall(10, 110, |g| {
        let (op, _) = random_bus_operator(g);
        let (seq, _) = op.position();
        for (qi, qw) in op.wins.iter().enumerate() {
            let ws = match op.queries[qi].query.window {
                pspice::query::WindowSpec::Count(ws) => ws,
                _ => unreachable!("q4 is count-windowed"),
            };
            for w in &qw.windows {
                assert!(seq < w.open_seq + ws, "expired window still open");
            }
            // oldest-first ordering
            let seqs: Vec<u64> = qw.windows.iter().map(|w| w.open_seq).collect();
            assert!(seqs.windows(2).all(|p| p[0] < p[1]));
        }
    });
}

#[test]
fn prop_random_drop_is_exact_and_conserving() {
    forall(10, 111, |g| {
        let (mut op, mut rng) = random_bus_operator(g);
        let before = op.pm_count();
        if before == 0 {
            return;
        }
        let rho = g.usize(0, before);
        let dropped = op.drop_random(rho, &mut rng);
        assert_eq!(dropped, rho.min(before));
        assert_eq!(op.pm_count(), before - dropped);
    });
}

#[test]
fn prop_drop_by_ids_removes_only_those() {
    forall(10, 112, |g| {
        let (mut op, _) = random_bus_operator(g);
        let mut refs = Vec::new();
        op.pm_refs(&mut refs);
        if refs.is_empty() {
            return;
        }
        let k = g.usize(1, refs.len());
        let mut victims: Vec<u64> = refs.iter().take(k).map(|r| r.pm_id).collect();
        victims.sort_unstable();
        let before = op.pm_count();
        let dropped = op.drop_pms(&victims);
        assert_eq!(dropped, k);
        let mut after = Vec::new();
        op.pm_refs(&mut after);
        assert_eq!(after.len(), before - k);
        for r in &after {
            assert!(victims.binary_search(&r.pm_id).is_err());
        }
    });
}

// ---------------------------------------------------------------- windows

#[test]
fn prop_count_window_remaining_decreases() {
    forall(20, 113, |g| {
        use pspice::events::Event;
        let mut qw = QueryWindows::default();
        let mut id = 0;
        let open_seq = g.usize(0, 1000) as u64;
        let e = Event::new(open_seq, open_seq, 0, &[0.0, 0.0, 1.0, 0.0]);
        qw.open(&e, &mut id);
        let ws = g.usize(10, 500) as u64;
        let spec = pspice::query::WindowSpec::Count(ws);
        let mut last = u64::MAX;
        for step in 0..ws {
            let cur = open_seq + step;
            let rem = qw.windows[0].remaining_events(spec, cur, 0, 1.0);
            assert!(rem <= last);
            assert!(rem >= 1, "window not yet expired must have events left");
            last = rem;
        }
    });
}
