//! End-to-end integration: every built-in query family runs through the
//! full three-phase experiment pipeline at a reduced scale, and the
//! paper's qualitative claims hold.

use pspice::config::ExperimentConfig;
use pspice::datasets::DatasetKind;
use pspice::harness::run_experiment;
use pspice::shedding::ShedderKind;

fn cfg(query: &str) -> ExperimentConfig {
    let (dataset, window, pattern_n) = match query {
        "q1" => (DatasetKind::Stock, 2_000, 0),
        "q2" => (DatasetKind::Stock, 3_000, 0),
        "q3" => (DatasetKind::Soccer, 1_500, 3),
        "q4" => (DatasetKind::Bus, 2_000, 4),
        _ => unreachable!(),
    };
    ExperimentConfig {
        query: query.into(),
        window,
        pattern_n,
        slide: 250,
        dataset,
        seed: 5,
        warmup: 25_000,
        events: 25_000,
        rate: 1.3,
        lb_ms: 0.5,
        shedder: ShedderKind::PSpice,
        model: pspice::model::ModelKind::Markov,
        weights: Vec::new(),
        cost_factors: Vec::new(),
        retrain_every: 0,
        drift_threshold: 0.01,
        shards: 1,
        batch: 256,
        ..ExperimentConfig::default()
    }
}

#[test]
fn all_query_families_run_end_to_end() {
    for q in ["q1", "q2", "q3", "q4"] {
        let r = run_experiment(&cfg(q)).unwrap_or_else(|e| panic!("{q}: {e:#}"));
        assert!(r.truth_total > 0, "{q}: ground truth empty");
        assert!(
            (0.0..=100.0).contains(&r.fn_percent),
            "{q}: fn={}",
            r.fn_percent
        );
        assert_eq!(r.false_positives, 0, "{q}: PM shedding must not invent CEs");
        assert!(r.capacity_ns > 0.0);
        assert!(r.match_probability > 0.0, "{q}: mp=0");
    }
}

#[test]
fn white_box_shedders_never_produce_false_positives() {
    for shedder in [ShedderKind::PSpice, ShedderKind::PSpiceMinus, ShedderKind::PmBaseline] {
        let mut c = cfg("q4");
        c.shedder = shedder;
        c.rate = 1.8; // heavy shedding
        let r = run_experiment(&c).unwrap();
        assert_eq!(r.false_positives, 0, "{:?}", shedder);
    }
}

#[test]
fn event_shedding_also_sound_on_these_queries() {
    // without negation operators, dropping events can only lose matches
    let mut c = cfg("q1");
    c.shedder = ShedderKind::EventBaseline;
    c.rate = 1.6;
    let r = run_experiment(&c).unwrap();
    assert_eq!(r.false_positives, 0);
    assert!(r.dropped_events > 0, "E-BL must actually shed events");
}

#[test]
fn latency_bound_violated_without_but_held_with_shedding() {
    let mut without = cfg("q1");
    without.shedder = ShedderKind::None;
    let r0 = run_experiment(&without).unwrap();
    assert!(
        r0.latency.violation_rate() > 0.2,
        "30% overload must blow an unshedded queue (viol={})",
        r0.latency.violation_rate()
    );

    let r1 = run_experiment(&cfg("q1")).unwrap();
    assert!(
        r1.latency.violation_rate() < 0.05,
        "pSPICE holds LB (viol={}, max={}ms)",
        r1.latency.violation_rate(),
        r1.latency.stats.max() / 1e6
    );
}

#[test]
fn higher_rate_means_more_false_negatives() {
    let lo = run_experiment(&cfg("q4")).unwrap();
    let mut hot = cfg("q4");
    hot.rate = 2.0;
    let hi = run_experiment(&hot).unwrap();
    assert!(
        hi.fn_percent >= lo.fn_percent - 1.0,
        "fn% should not shrink with overload: {:.1} -> {:.1}",
        lo.fn_percent,
        hi.fn_percent
    );
    // both overloads force drops (totals aren't comparable: heavier
    // shedding leaves fewer live PMs to drop later)
    assert!(hi.dropped_pms > 0 && lo.dropped_pms > 0);
}

#[test]
fn pspice_beats_random_on_q1() {
    let p = run_experiment(&cfg("q1")).unwrap();
    let mut c = cfg("q1");
    c.shedder = ShedderKind::PmBaseline;
    let b = run_experiment(&c).unwrap();
    assert!(
        p.fn_percent <= b.fn_percent + 2.0,
        "pspice {:.1}% vs pm-bl {:.1}%",
        p.fn_percent,
        b.fn_percent
    );
}

#[test]
fn results_are_deterministic() {
    let a = run_experiment(&cfg("q4")).unwrap();
    let b = run_experiment(&cfg("q4")).unwrap();
    assert_eq!(a.fn_percent, b.fn_percent);
    assert_eq!(a.dropped_pms, b.dropped_pms);
    assert_eq!(a.truth_total, b.truth_total);
}

#[test]
fn config_file_round_trip_drives_runner() {
    let dir = std::env::temp_dir().join("pspice_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        r#"
        [experiment]
        query = "q4"
        window = 2000
        pattern_n = 4
        slide = 250
        dataset = "bus"
        seed = 5
        warmup = 20000
        events = 15000
        rate = 1.3
        lb_ms = 0.5
        shedder = "pspice"
        "#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_file(&path).unwrap();
    let r = run_experiment(&cfg).unwrap();
    assert!(r.truth_total > 0);
}
