//! Scorecard contract tests: the manifest's determinism promise (same
//! seeds + same config ⇒ identical manifest hash AND bit-identical
//! primary metrics under the sim clock), and the trend gates'
//! end-to-end behavior against a real ledger file (vacuous pass with
//! no baseline, named-metric failure on an injected regression).

use std::path::PathBuf;

use pspice::config::{ExperimentConfig, ScorecardConfig};
use pspice::datasets::DatasetKind;
use pspice::scorecard::gates;
use pspice::scorecard::ledger::{entry_cell_mean, Ledger, LedgerEntry};
use pspice::scorecard::manifest::git_commit;
use pspice::scorecard::{run_cells, CellMetrics, RunManifest, PRIMARY_METRICS};
use pspice::shedding::ShedderKind;

/// One reduced grid cell (bus/q4, pSPICE) at test scale — small enough
/// to run twice, big enough to shed under overload.
fn tiny_grid() -> Vec<ExperimentConfig> {
    vec![ExperimentConfig {
        query: "q4".into(),
        window: 2_000,
        pattern_n: 4,
        slide: 250,
        dataset: DatasetKind::Bus,
        events: 6_000,
        warmup: 10_000,
        rate: 1.4,
        lb_ms: 0.05,
        shedder: ShedderKind::PSpice,
        ..ExperimentConfig::default()
    }]
}

fn tiny_manifest(cells: Vec<ExperimentConfig>, seeds: Vec<u64>) -> RunManifest {
    RunManifest {
        smoke: true,
        commit: git_commit(),
        seeds,
        sc: ScorecardConfig {
            reps: 2,
            base_seed: 3,
            ..ScorecardConfig::default()
        },
        cells,
    }
}

#[test]
fn same_manifest_means_identical_hash_and_primary_metrics() {
    let cfgs = tiny_grid();
    let seeds = vec![3u64, 4];

    let m1 = tiny_manifest(cfgs.clone(), seeds.clone());
    let m2 = tiny_manifest(cfgs.clone(), seeds.clone());
    assert_eq!(m1.hash(), m2.hash(), "same inputs, same manifest hash");

    let run1 = run_cells(&cfgs, &seeds).unwrap();
    let run2 = run_cells(&cfgs, &seeds).unwrap();
    assert_eq!(run1.len(), 1);
    assert_eq!(run1[0].reps.len(), 2, "one rep per seed");
    assert_eq!(run1[0].key(), "pspice/bus");

    for (c1, c2) in run1.iter().zip(&run2) {
        for metric in PRIMARY_METRICS {
            let s1 = c1.samples(metric);
            let s2 = c2.samples(metric);
            for (a, b) in s1.iter().zip(&s2) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{metric} must be bit-identical across identical runs \
                     ({a} vs {b})"
                );
            }
        }
    }
    // the virtual-time substrate really measured something
    let p95 = run1[0].ci("p95_ms");
    assert!(p95.mean > 0.0, "p95 must be positive, got {}", p95.mean);
    assert_eq!(p95.n, 2);
}

#[test]
fn different_seed_schedule_changes_the_hash() {
    let cfgs = tiny_grid();
    let a = tiny_manifest(cfgs.clone(), vec![3, 4]);
    let b = tiny_manifest(cfgs, vec![3, 5]);
    assert_ne!(a.hash(), b.hash());
}

#[test]
fn ledger_gates_pass_vacuously_then_catch_injected_regression() {
    let dir = std::env::temp_dir().join("pspice_scorecard_it");
    std::fs::create_dir_all(&dir).unwrap();
    let ledger_path: PathBuf = dir.join("SCORECARD.jsonl");
    let _ = std::fs::remove_file(&ledger_path);

    let cfgs = tiny_grid();
    let seeds = vec![3u64, 4];
    let manifest = tiny_manifest(cfgs.clone(), seeds.clone());
    let sc = manifest.sc.clone();
    let cells = run_cells(&cfgs, &seeds).unwrap();

    // empty ledger: no baseline, gates pass vacuously
    let ledger = Ledger::read(&ledger_path).unwrap();
    assert!(ledger.entries.is_empty());
    assert!(ledger.baseline(true, &manifest.hash()).is_none());
    assert!(gates::evaluate(None, &cells, &sc).is_empty());

    // append the establishing entry and re-read it as the baseline
    let entry = LedgerEntry {
        manifest: manifest.clone(),
        cells: cells.clone(),
        blessed: false,
        bench: Vec::new(),
    };
    Ledger::append_line(&ledger_path, &entry.to_line()).unwrap();
    let ledger = Ledger::read(&ledger_path).unwrap();
    let baseline = ledger.baseline(true, &manifest.hash()).unwrap();
    let recorded = entry_cell_mean(baseline, "pspice/bus", "p95_ms").unwrap();
    let measured = cells[0].ci("p95_ms").mean;
    assert_eq!(
        recorded.to_bits(),
        measured.to_bits(),
        "the ledger line round-trips the measured mean exactly"
    );

    // the same measurements against their own baseline: clean
    assert!(gates::evaluate(Some(baseline), &cells, &sc).is_empty());

    // inject a >5% latency regression and demand a named violation
    let mut worse: Vec<CellMetrics> = cells.clone();
    for rep in &mut worse[0].reps {
        rep.p95_ms *= 1.5;
    }
    let violations = gates::evaluate(Some(baseline), &worse, &sc);
    assert_eq!(violations.len(), 1, "{violations:?}");
    assert_eq!(violations[0].cell, "pspice/bus");
    assert_eq!(violations[0].metric, "p95_ms");
    let msg = violations[0].to_string();
    assert!(
        msg.contains("pspice/bus") && msg.contains("p95_ms"),
        "the error must name the cell and metric: {msg}"
    );

    // a different manifest (full-scale flag) finds no baseline here
    let mut full = manifest.clone();
    full.smoke = false;
    assert!(ledger.baseline(false, &full.hash()).is_none());
}
