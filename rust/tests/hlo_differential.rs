//! Differential validation of the AOT/PJRT path against the pure-rust
//! oracle: the compiled artifact must reproduce the fallback engine's
//! tables bit-close for random chains of every supported shape,
//! including padded ones.
//!
//! Skips (with a loud message) when `artifacts/` has not been built —
//! run `make artifacts` first.  The whole suite needs the PJRT engine,
//! which only exists with the `xla` cargo feature.
#![cfg(feature = "xla")]

use pspice::linalg::Mat;
use pspice::runtime::{ArtifactManifest, FallbackEngine, ModelEngine, PjrtEngine};
use pspice::testing::{forall, Gen};

fn engine() -> Option<PjrtEngine> {
    let dir = ArtifactManifest::default_dir();
    match PjrtEngine::load(&dir) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP hlo_differential: no artifacts ({err:#}) — run `make artifacts`");
            None
        }
    }
}

fn random_chain(g: &mut Gen, m: usize) -> (Mat, Vec<f64>) {
    let t = g.stochastic_matrix(m);
    let mut r: Vec<f64> = (0..m).map(|_| g.f64(0.0, 5.0)).collect();
    r[m - 1] = 0.0;
    (t, r)
}

fn assert_tables_close(
    a: &pspice::linalg::markov::MarkovTables,
    b: &pspice::linalg::markov::MarkovTables,
    tol: f64,
    what: &str,
) {
    assert_eq!(a.completion.len(), b.completion.len(), "{what}: bins");
    for j in 0..a.completion.len() {
        for s in 0..a.completion[j].len() {
            let (x, y) = (a.completion[j][s], b.completion[j][s]);
            assert!((x - y).abs() <= tol, "{what}: c[{j}][{s}] {x} vs {y}");
            let (x, y) = (a.remaining_time[j][s], b.remaining_time[j][s]);
            // remaining time magnitudes grow with bins: relative tol
            let scale = y.abs().max(1.0);
            assert!(
                (x - y).abs() <= tol * scale,
                "{what}: tau[{j}][{s}] {x} vs {y}"
            );
        }
    }
}

#[test]
fn pjrt_matches_rust_oracle_over_random_chains() {
    let Some(mut pjrt) = engine() else { return };
    let mut fallback = FallbackEngine;
    forall(12, 2024, |g| {
        let batch = g.usize(1, 4);
        let m = g.usize(2, 16);
        let nbins = g.usize(1, 128);
        let chains: Vec<_> = (0..batch).map(|_| random_chain(g, m)).collect();
        let a = pjrt.build_tables(&chains, nbins).expect("pjrt");
        let b = fallback.build_tables(&chains, nbins).expect("fallback");
        for (i, (ta, tb)) in a.iter().zip(&b).enumerate() {
            assert_tables_close(ta, tb, 2e-4, &format!("chain {i} (m={m}, nbins={nbins})"));
        }
    });
}

#[test]
fn pjrt_handles_mixed_state_counts_in_one_batch() {
    let Some(mut pjrt) = engine() else { return };
    let mut fallback = FallbackEngine;
    forall(6, 77, |g| {
        // chains of different m in the same batch exercise per-chain padding
        let chains: Vec<_> = [3usize, 5, 11, 2]
            .iter()
            .map(|&m| random_chain(g, m))
            .collect();
        let a = pjrt.build_tables(&chains, 64).expect("pjrt");
        let b = fallback.build_tables(&chains, 64).expect("fallback");
        for (ta, tb) in a.iter().zip(&b) {
            assert_tables_close(ta, tb, 2e-4, "mixed batch");
        }
    });
}

#[test]
fn pjrt_uses_largest_variant_for_q2_sized_patterns() {
    let Some(mut pjrt) = engine() else { return };
    // m=15 (Q2) with 4 patterns and 512 bins needs the B8_M32_N512 variant
    let mut g_holder = None;
    forall(1, 5, |g| {
        let chains: Vec<_> = (0..4).map(|_| random_chain(g, 15)).collect();
        g_holder = Some(chains);
    });
    let chains = g_holder.unwrap();
    let out = pjrt.build_tables(&chains, 512).expect("pjrt big variant");
    assert_eq!(out.len(), 4);
    assert_eq!(out[0].completion.len(), 512);
    let mut fallback = FallbackEngine;
    let b = fallback.build_tables(&chains, 512).unwrap();
    assert_tables_close(&out[0], &b[0], 5e-4, "q2-sized");
}

#[test]
fn pjrt_compiles_each_variant_once() {
    let Some(mut pjrt) = engine() else { return };
    let t = Mat::from_rows(2, 2, &[0.5, 0.5, 0.0, 1.0]);
    let chain = vec![(t, vec![1.0, 0.0])];
    pjrt.build_tables(&chain, 8).unwrap();
    let after_first = pjrt.compiled_count();
    for _ in 0..5 {
        pjrt.build_tables(&chain, 8).unwrap();
    }
    assert_eq!(pjrt.compiled_count(), after_first, "executables are cached");
}

#[test]
fn pjrt_rejects_oversized_problems() {
    let Some(mut pjrt) = engine() else { return };
    // m=64 exceeds every built variant
    let m = 64;
    let mut t = Mat::zeros(m, m);
    for i in 0..m {
        t[(i, i)] = 1.0;
    }
    let r = vec![0.0; m];
    assert!(pjrt.build_tables(&[(t, r)], 8).is_err());
}
