//! CLI for the invariant auditor.
//!
//! ```text
//! cargo run -p pallas-audit                     # scan rust/src, human output
//! cargo run -p pallas-audit -- --json           # machine-readable report
//! cargo run -p pallas-audit -- --root path/src  # scan another tree
//! cargo run -p pallas-audit -- --baseline b.json
//! ```
//!
//! Exit codes: `0` clean, `1` unsuppressed findings, `2` usage or I/O
//! error.  CI's `static-audit` job runs this with the committed (empty)
//! baseline and fails the build on exit 1.

use std::path::PathBuf;
use std::process::ExitCode;

use pallas_audit::{apply_baseline, parse_baseline, scan_tree, to_json};

struct Opts {
    root: PathBuf,
    json: bool,
    baseline: Option<PathBuf>,
}

fn usage() -> &'static str {
    "pallas-audit: static invariant scanner for the pSPICE reproduction\n\
     \n\
     usage: pallas-audit [--root DIR] [--baseline FILE.json] [--json]\n\
     \n\
     --root DIR        source tree to scan (default: the repo's rust/src)\n\
     --baseline FILE   JSON array of \"file:lint\" keys to ignore\n\
     --json            emit the machine-readable report on stdout\n"
}

fn parse_opts() -> Result<Opts, String> {
    // default root: rust/src relative to this crate's manifest
    // (rust/tools/audit → ../../src), so `cargo run -p pallas-audit`
    // does the right thing from anywhere in the workspace
    let default_root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../src");
    let mut opts = Opts {
        root: default_root,
        json: false,
        baseline: None,
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => {
                let p = args.next().ok_or_else(|| "--root needs a path".to_string())?;
                opts.root = PathBuf::from(p);
            }
            "--baseline" => {
                let p = args
                    .next()
                    .ok_or_else(|| "--baseline needs a path".to_string())?;
                opts.baseline = Some(PathBuf::from(p));
            }
            "--json" => opts.json = true,
            "--help" | "-h" => return Err(String::new()),
            other => return Err(format!("unknown argument: {other}")),
        }
    }
    Ok(opts)
}

fn main() -> ExitCode {
    let opts = match parse_opts() {
        Ok(o) => o,
        Err(msg) => {
            if msg.is_empty() {
                print!("{}", usage());
                return ExitCode::SUCCESS;
            }
            eprintln!("error: {msg}\n\n{}", usage());
            return ExitCode::from(2);
        }
    };

    let baseline: Vec<String> = match &opts.baseline {
        None => Vec::new(),
        Some(p) => {
            let text = match std::fs::read_to_string(p) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("error: reading baseline {}: {e}", p.display());
                    return ExitCode::from(2);
                }
            };
            match parse_baseline(&text) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("error: parsing baseline {}: {e}", p.display());
                    return ExitCode::from(2);
                }
            }
        }
    };

    let findings = match scan_tree(&opts.root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: scanning {}: {e}", opts.root.display());
            return ExitCode::from(2);
        }
    };
    let findings = apply_baseline(findings, &baseline);

    if opts.json {
        print!("{}", to_json(&findings));
    } else {
        for f in &findings {
            println!("{f}");
        }
        println!(
            "pallas-audit: {} finding{} in {}",
            findings.len(),
            if findings.len() == 1 { "" } else { "s" },
            opts.root.display()
        );
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
