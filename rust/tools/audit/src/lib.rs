//! `pallas-audit`: token-level static auditor for the pSPICE
//! reproduction's invariant catalog.
//!
//! The pipeline's headline guarantee is *bit-exact determinism*: the
//! same trace and seed produce byte-identical results across shard
//! counts, recovery paths, and machines.  That property is enforced by
//! regression pins (`pipeline_regression`, `shed_equivalence`, the
//! chaos zero-fault pins), but a pin only fires *after* someone writes
//! the nondeterminism and lands it.  This tool rejects the usual
//! sources lexically, before a test ever runs:
//!
//! * **det-hash** — `HashMap`/`HashSet` anywhere in a result-affecting
//!   module.  Hash iteration order is seeded per-process; one
//!   `for (k, v) in map` in a shedding decision silently breaks
//!   equivalence.  Ordered containers (`BTreeMap`/`BTreeSet`) or sorted
//!   slices are the sanctioned replacements.
//! * **det-float-ord** — `partial_cmp` in a result-affecting module.
//!   Float comparisons must use `total_cmp` (NaN-safe total order);
//!   `partial_cmp().unwrap()` panics on NaN and
//!   `unwrap_or(Equal)` makes sort order depend on the sort algorithm.
//! * **det-rand** — unseeded randomness (`thread_rng`, `RandomState`,
//!   `from_entropy`) in a result-affecting module.
//! * **clock-wall** — `Instant::now`/`SystemTime` outside
//!   `sim/clock.rs`.  Wall time must flow through the `Clock` plane;
//!   instrumentation-only reads use `sim::WallTimer` or carry an
//!   annotation (below).
//! * **panic-path** — `unwrap`/`expect`/`panic!`/`unreachable!`/
//!   `todo!`/`unimplemented!` in non-test `runtime/sharded/` code.  The
//!   sharded coordinator must degrade worker faults into
//!   `ShardFailure`s; a panic on the supervision path kills the whole
//!   pipeline.
//! * **alloc-hot** — allocating constructors (`Vec::new`, `collect`,
//!   `format!`, …) inside a function marked `// audit: no-alloc`.  The
//!   markers sit on the per-event and shedding hot paths whose
//!   allocation-freedom the overhead benchmarks assume.
//!
//! Deliberate exceptions are annotated in source:
//!
//! ```text
//! // audit:allow(wall-clock): wall throughput instrumentation only
//! let wall_start = Instant::now();
//! ```
//!
//! An allow covers the same line or sits in the contiguous comment
//! block directly above the flagged line, and **must** carry a reason
//! after the colon — a bare `audit:allow(key)` is itself reported as
//! `bad-suppression`.  Allow keys: `hash-iter`, `float-ord`, `rand`,
//! `wall-clock`, `panic`, `alloc`.
//!
//! The scan is lexical on purpose: no `syn`, no rustc plumbing, zero
//! dependencies, so it runs in the offline image and in CI in
//! milliseconds.  Comments and literal contents are stripped first,
//! `#[cfg(test)]` regions are skipped by brace matching, and tokens
//! match on identifier boundaries — so a mention of `HashMap` in a doc
//! comment or a string is never a finding.

use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

/// Result-affecting module prefixes (relative to the source root):
/// everything here feeds the bit-exact pipeline results.
const RESULT_SCOPES: &[&str] = &[
    "operator/",
    "windows/",
    "shedding/",
    "model/",
    "nfa/",
    "runtime/sharded/",
];

/// Individual result-affecting files outside the scoped directories.
const RESULT_FILES: &[&str] = &["metrics/qor.rs"];

/// The one place wall-clock reads are legitimate: the `Clock` plane.
const CLOCK_EXEMPT: &[&str] = &["sim/clock.rs"];

/// Panic-free scope: the sharded supervision/worker paths.
const PANIC_SCOPE: &[&str] = &["runtime/sharded/"];

const HASH_TOKENS: &[&str] = &["HashMap", "HashSet"];
const RAND_TOKENS: &[&str] = &["thread_rng", "RandomState", "from_entropy"];
const PANIC_TOKENS: &[&str] = &[
    "unwrap",
    "expect",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];
const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "VecDeque::new",
    "String::new",
    "String::from",
    "Box::new",
    "Arc::new",
    "Rc::new",
    "HashMap::new",
    "HashSet::new",
    "BTreeMap::new",
    "BTreeSet::new",
    "with_capacity",
    "to_vec",
    "to_string",
    "to_owned",
    "collect",
    "vec!",
    "format!",
];

/// Alloc tokens that are method-ish names: only flagged when invoked
/// (followed by `(`) or turbofished (followed by `:`), so a field
/// named `collect` or a doc mention never fires.
const ALLOC_CALL_ONLY: &[&str] = &["collect", "with_capacity", "to_vec", "to_string", "to_owned"];

/// The lint catalog.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Lint {
    /// Hash container in a result-affecting module.
    DetHash,
    /// `partial_cmp` in a result-affecting module.
    DetFloatOrd,
    /// Unseeded randomness in a result-affecting module.
    DetRand,
    /// Wall-clock read outside the `Clock` plane.
    ClockWall,
    /// Panicking call on a sharded supervision path.
    PanicPath,
    /// Allocation inside an `audit: no-alloc` function.
    AllocHot,
    /// `audit:allow(..)` without a written reason.
    BadSuppression,
}

impl Lint {
    /// Stable lint id (used in JSON output and baseline keys).
    pub fn id(self) -> &'static str {
        match self {
            Lint::DetHash => "det-hash",
            Lint::DetFloatOrd => "det-float-ord",
            Lint::DetRand => "det-rand",
            Lint::ClockWall => "clock-wall",
            Lint::PanicPath => "panic-path",
            Lint::AllocHot => "alloc-hot",
            Lint::BadSuppression => "bad-suppression",
        }
    }

    /// The `audit:allow(<key>)` key that suppresses this lint.
    pub fn allow_key(self) -> &'static str {
        match self {
            Lint::DetHash => "hash-iter",
            Lint::DetFloatOrd => "float-ord",
            Lint::DetRand => "rand",
            Lint::ClockWall => "wall-clock",
            Lint::PanicPath => "panic",
            Lint::AllocHot => "alloc",
            Lint::BadSuppression => "",
        }
    }

    fn rationale(self) -> &'static str {
        match self {
            Lint::DetHash => {
                "in a result-affecting module: hash iteration order is nondeterministic"
            }
            Lint::DetFloatOrd => {
                "in a result-affecting module: float ordering must use total_cmp"
            }
            Lint::DetRand => "unseeded randomness in a result-affecting module",
            Lint::ClockWall => {
                "outside sim/clock.rs: wall time must flow through the Clock plane"
            }
            Lint::PanicPath => {
                "on a sharded coordinator/worker path: must degrade to ShardFailure, never panic"
            }
            Lint::AllocHot => "inside an `audit: no-alloc` function",
            Lint::BadSuppression => "",
        }
    }
}

/// One audit finding, anchored to a file and 1-based line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Which lint fired.
    pub lint: Lint,
    /// Human-readable rationale.
    pub message: String,
}

impl Finding {
    /// Baseline key: line numbers drift with unrelated edits, so
    /// suppression keys are `file:lint` only.
    pub fn key(&self) -> String {
        format!("{}:{}", self.file, self.lint.id())
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line,
            self.lint.id(),
            self.message
        )
    }
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Split `source` into parallel per-line `(code, comments)` vectors:
/// `code` has comments and the *contents* of string/char literals
/// blanked to spaces (delimiters kept), `comments` collects comment
/// text per line.  Column positions in `code` line up with the source.
fn strip(source: &str) -> (Vec<String>, Vec<String>) {
    #[derive(PartialEq)]
    enum Mode {
        Code,
        Block,
        Str,
        RawStr,
        Chr,
    }
    let cs: Vec<char> = source.chars().collect();
    let n = cs.len();
    let mut code: Vec<String> = Vec::new();
    let mut comments: Vec<String> = Vec::new();
    let mut cur_code = String::new();
    let mut cur_comment = String::new();
    let mut mode = Mode::Code;
    let mut block_depth = 0usize;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    macro_rules! endline {
        () => {{
            code.push(std::mem::take(&mut cur_code));
            comments.push(std::mem::take(&mut cur_comment));
        }};
    }
    while i < n {
        let c = cs[i];
        if c == '\n' {
            endline!();
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                if c == '/' && i + 1 < n && cs[i + 1] == '/' {
                    // line comment: consume to EOL into the comment buffer
                    let mut j = i;
                    while j < n && cs[j] != '\n' {
                        j += 1;
                    }
                    cur_comment.extend(&cs[i..j]);
                    cur_code.extend(std::iter::repeat(' ').take(j - i));
                    i = j;
                } else if c == '/' && i + 1 < n && cs[i + 1] == '*' {
                    mode = Mode::Block;
                    block_depth = 1;
                    cur_code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    mode = Mode::Str;
                    cur_code.push('"');
                    i += 1;
                } else if c == 'r' && (i == 0 || !is_ident(cs[i - 1])) {
                    // raw string r"..." or r#"..."#
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && cs[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && cs[j] == '"' {
                        mode = Mode::RawStr;
                        raw_hashes = h;
                        cur_code.extend(std::iter::repeat(' ').take(j - i + 1));
                        i = j + 1;
                    } else {
                        cur_code.push(c);
                        i += 1;
                    }
                } else if c == 'b'
                    && i + 1 < n
                    && cs[i + 1] == '"'
                    && (i == 0 || !is_ident(cs[i - 1]))
                {
                    mode = Mode::Str;
                    cur_code.push_str(" \"");
                    i += 2;
                } else if c == '\'' {
                    // char literal vs. lifetime
                    if i + 1 < n && cs[i + 1] == '\\' {
                        mode = Mode::Chr;
                        cur_code.push('\'');
                        i += 1;
                    } else if i + 2 < n && cs[i + 2] == '\'' {
                        cur_code.push_str("'x'");
                        i += 3;
                    } else {
                        cur_code.push('\''); // lifetime
                        i += 1;
                    }
                } else {
                    cur_code.push(c);
                    i += 1;
                }
            }
            Mode::Block => {
                if c == '*' && i + 1 < n && cs[i + 1] == '/' {
                    block_depth -= 1;
                    i += 2;
                    if block_depth == 0 {
                        mode = Mode::Code;
                    }
                    cur_code.push_str("  ");
                } else if c == '/' && i + 1 < n && cs[i + 1] == '*' {
                    block_depth += 1;
                    cur_code.push_str("  ");
                    i += 2;
                } else {
                    cur_comment.push(c);
                    cur_code.push(' ');
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    cur_code.push_str("  ");
                    i += 2;
                    // an escaped newline ends the physical line
                    if i >= 1 && i - 1 < n && cs[i - 1] == '\n' {
                        endline!();
                    }
                } else if c == '"' {
                    mode = Mode::Code;
                    cur_code.push('"');
                    i += 1;
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
            Mode::RawStr => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && cs[j] == '#' && h < raw_hashes {
                        h += 1;
                        j += 1;
                    }
                    if h == raw_hashes {
                        mode = Mode::Code;
                        cur_code.extend(std::iter::repeat(' ').take(j - i));
                        i = j;
                    } else {
                        cur_code.push(' ');
                        i += 1;
                    }
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
            Mode::Chr => {
                if c == '\\' {
                    cur_code.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    mode = Mode::Code;
                    cur_code.push('\'');
                    i += 1;
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
        }
    }
    endline!();
    (code, comments)
}

/// Byte offsets in `line` where `tok` occurs on identifier boundaries.
/// Macro tokens (trailing `!`) must be followed by the bang.
fn find_token(line: &str, tok: &str) -> Vec<usize> {
    let bare = tok.trim_end_matches('!');
    let is_macro = tok.ends_with('!');
    let bytes = line.as_bytes();
    let mut out = Vec::new();
    let mut start = 0usize;
    while let Some(off) = line[start..].find(bare) {
        let k = start + off;
        start = k + 1;
        let before_ok = k == 0 || !is_ident_byte(bytes[k - 1]);
        let after = k + bare.len();
        if is_macro {
            if before_ok && after < bytes.len() && bytes[after] == b'!' {
                out.push(k);
            }
            continue;
        }
        let after_ok = after >= bytes.len() || !is_ident_byte(bytes[after]);
        if before_ok && after_ok {
            out.push(k);
        }
    }
    out
}

/// Match braces in `text` starting at the `{` at byte `open`; returns
/// the byte offset of the closing `}` (or end of text).
fn match_braces(text: &str, open: usize) -> usize {
    let bytes = text.as_bytes();
    let mut depth = 0usize;
    let mut j = open;
    while j < bytes.len() {
        match bytes[j] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    j
}

fn line_of(text: &str, byte: usize) -> usize {
    text.as_bytes()[..byte.min(text.len())]
        .iter()
        .filter(|&&b| b == b'\n')
        .count()
}

/// 0-based inclusive line ranges covered by `#[cfg(test)]` items.
fn test_regions(code: &[String]) -> Vec<(usize, usize)> {
    let text = code.join("\n");
    let mut regions = Vec::new();
    let mut idx = 0usize;
    while let Some(off) = text[idx..].find("#[cfg(test)]") {
        let k = idx + off;
        match text[k..].find('{') {
            Some(boff) => {
                let b = k + boff;
                let j = match_braces(&text, b);
                regions.push((line_of(&text, k), line_of(&text, j)));
                idx = j.max(k + 1);
            }
            None => break,
        }
    }
    regions
}

fn in_regions(line_idx: usize, regions: &[(usize, usize)]) -> bool {
    regions.iter().any(|&(a, b)| a <= line_idx && line_idx <= b)
}

/// Body line ranges (0-based inclusive) of functions marked with an
/// `// audit: no-alloc` comment: the marker binds to the next `fn`.
fn noalloc_regions(code: &[String], comments: &[String]) -> Vec<(usize, usize)> {
    let text = code.join("\n");
    let mut line_start = Vec::with_capacity(code.len() + 1);
    line_start.push(0usize);
    for l in code {
        line_start.push(line_start.last().unwrap() + l.len() + 1);
    }
    let mut out = Vec::new();
    for (i, cm) in comments.iter().enumerate() {
        if !cm.contains("audit: no-alloc") {
            continue;
        }
        // first `fn` token at or after the marker line
        let mut pos = line_start[i];
        let mut fn_at = None;
        while let Some(off) = text[pos..].find("fn") {
            let k = pos + off;
            let bytes = text.as_bytes();
            let before_ok = k == 0 || !is_ident_byte(bytes[k - 1]);
            let after_ok = k + 2 >= bytes.len() || !is_ident_byte(bytes[k + 2]);
            if before_ok && after_ok {
                fn_at = Some(k);
                break;
            }
            pos = k + 1;
        }
        let Some(k) = fn_at else { continue };
        let Some(boff) = text[k..].find('{') else { continue };
        let b = k + boff;
        let j = match_braces(&text, b);
        out.push((line_of(&text, b), line_of(&text, j)));
    }
    out
}

/// Does an `audit:allow(<key>)` cover line `line_idx` — on the same
/// line or in the contiguous comment block directly above?  Returns
/// `(found, has_reason)`.
fn allows(code: &[String], comments: &[String], line_idx: usize, key: &str) -> (bool, bool) {
    let marker = format!("audit:allow({key})");
    let check = |li: usize| -> Option<bool> {
        let cm = &comments[li];
        let k = cm.find(&marker)?;
        let rest = cm[k + marker.len()..].trim_start();
        Some(rest.starts_with(':') && !rest[1..].trim().is_empty())
    };
    if let Some(r) = check(line_idx) {
        return (true, r);
    }
    let mut li = line_idx;
    // walk up through comment-only lines (blank code, non-empty comment)
    while li > 0 {
        li -= 1;
        if !code[li].trim().is_empty() || comments[li].trim().is_empty() {
            break;
        }
        if let Some(r) = check(li) {
            return (true, r);
        }
    }
    (false, false)
}

/// Scan one file's source.  `rel` is the `/`-separated path relative to
/// the source root (it selects which scopes apply).
pub fn scan_source(rel: &str, source: &str) -> Vec<Finding> {
    let (code, comments) = strip(source);
    debug_assert_eq!(code.len(), comments.len());
    let tests = test_regions(&code);
    let mut findings = Vec::new();

    let emit = |lint: Lint, i: usize, tok: &str, findings: &mut Vec<Finding>| {
        if in_regions(i, &tests) {
            return;
        }
        let (found, reasoned) = allows(&code, &comments, i, lint.allow_key());
        if found && reasoned {
            return;
        }
        if found {
            findings.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                lint: Lint::BadSuppression,
                message: format!("audit:allow({}) without a written reason", lint.allow_key()),
            });
            return;
        }
        findings.push(Finding {
            file: rel.to_string(),
            line: i + 1,
            lint,
            message: format!("`{tok}` {}", lint.rationale()),
        });
    };

    let in_result =
        RESULT_SCOPES.iter().any(|s| rel.starts_with(s)) || RESULT_FILES.contains(&rel);
    let in_panic = PANIC_SCOPE.iter().any(|s| rel.starts_with(s));
    let clock_exempt = CLOCK_EXEMPT.contains(&rel);

    for (i, line) in code.iter().enumerate() {
        if in_result {
            for tok in HASH_TOKENS {
                for _ in find_token(line, tok) {
                    emit(Lint::DetHash, i, tok, &mut findings);
                }
            }
            for _ in find_token(line, "partial_cmp") {
                emit(Lint::DetFloatOrd, i, "partial_cmp", &mut findings);
            }
            for tok in RAND_TOKENS {
                for _ in find_token(line, tok) {
                    emit(Lint::DetRand, i, tok, &mut findings);
                }
            }
        }
        if !clock_exempt {
            for k in find_token(line, "now") {
                if line[..k].trim_end().ends_with("Instant::") {
                    emit(Lint::ClockWall, i, "Instant::now", &mut findings);
                }
            }
            for _ in find_token(line, "SystemTime") {
                emit(Lint::ClockWall, i, "SystemTime", &mut findings);
            }
        }
        if in_panic {
            for tok in PANIC_TOKENS {
                for k in find_token(line, tok) {
                    if tok.ends_with('!') {
                        emit(Lint::PanicPath, i, tok, &mut findings);
                    } else {
                        // bare unwrap/expect only as a call
                        let after = k + tok.len();
                        if line.as_bytes().get(after) == Some(&b'(') {
                            emit(Lint::PanicPath, i, tok, &mut findings);
                        }
                    }
                }
            }
        }
    }

    for (a, b) in noalloc_regions(&code, &comments) {
        for i in a..=b.min(code.len().saturating_sub(1)) {
            if in_regions(i, &tests) {
                continue;
            }
            for tok in ALLOC_TOKENS {
                for k in find_token(&code[i], tok) {
                    if ALLOC_CALL_ONLY.contains(tok) {
                        let after = k + tok.len();
                        match code[i].as_bytes().get(after) {
                            Some(&b'(') | Some(&b':') => {}
                            _ => continue,
                        }
                    }
                    emit(Lint::AllocHot, i, tok, &mut findings);
                }
            }
        }
    }

    findings
}

/// Scan every `.rs` file under `root` (sorted walk, so output order is
/// stable across machines).  Returns findings sorted by
/// `(file, line, lint)`.
pub fn scan_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for p in &files {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        let source = fs::read_to_string(p)?;
        findings.extend(scan_source(&rel, &source));
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.lint.id()).cmp(&(b.file.as_str(), b.line, b.lint.id()))
    });
    Ok(findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Drop findings whose `file:lint` key appears in the baseline.  The
/// committed baseline is required to be empty (ISSUE/CI policy); the
/// mechanism exists so a future migration can land incrementally
/// without weakening the gate for everything else.
pub fn apply_baseline(findings: Vec<Finding>, baseline: &[String]) -> Vec<Finding> {
    findings
        .into_iter()
        .filter(|f| !baseline.iter().any(|k| *k == f.key()))
        .collect()
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Machine-readable report: `{"count": N, "findings": [...]}`.
pub fn to_json(findings: &[Finding]) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"count\": {},\n", findings.len()));
    out.push_str("  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"lint\": \"{}\", \"message\": \"{}\"}}",
            json_escape(&f.file),
            f.line,
            f.lint.id(),
            json_escape(&f.message)
        ));
    }
    if !findings.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("]\n}\n");
    out
}

/// Parse a baseline file: a JSON array of `"file:lint"` strings (the
/// only JSON this zero-dependency tool needs to read).
pub fn parse_baseline(text: &str) -> Result<Vec<String>, String> {
    let t = text.trim();
    let inner = t
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| "baseline must be a JSON array of strings".to_string())?;
    let mut out = Vec::new();
    let cs: Vec<char> = inner.chars().collect();
    let mut i = 0usize;
    loop {
        while i < cs.len() && (cs[i].is_whitespace() || cs[i] == ',') {
            i += 1;
        }
        if i >= cs.len() {
            break;
        }
        if cs[i] != '"' {
            return Err(format!("unexpected character {:?} in baseline", cs[i]));
        }
        i += 1;
        let mut s = String::new();
        while i < cs.len() && cs[i] != '"' {
            if cs[i] == '\\' && i + 1 < cs.len() {
                i += 1;
                match cs[i] {
                    'n' => s.push('\n'),
                    't' => s.push('\t'),
                    c => s.push(c),
                }
            } else {
                s.push(cs[i]);
            }
            i += 1;
        }
        if i >= cs.len() {
            return Err("unterminated string in baseline".to_string());
        }
        i += 1; // closing quote
        out.push(s);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lints(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.lint.id()).collect()
    }

    #[test]
    fn det_hash_fires_only_in_result_scope() {
        let src = "use std::collections::HashMap;\n\
                   fn f() { let m: HashMap<u32, u32> = HashMap::new(); }\n";
        let in_scope = scan_source("operator/foo.rs", src);
        assert!(in_scope.iter().all(|f| f.lint == Lint::DetHash));
        assert_eq!(in_scope.len(), 3, "import + type + ctor all flagged");
        assert_eq!(in_scope[0].line, 1);
        let out_of_scope = scan_source("util/foo.rs", src);
        assert!(out_of_scope.is_empty(), "util/ is not a result scope");
    }

    #[test]
    fn qor_rs_is_a_result_file() {
        let src = "use std::collections::HashSet;\n";
        assert_eq!(lints(&scan_source("metrics/qor.rs", src)), ["det-hash"]);
        assert!(scan_source("metrics/latency.rs", src).is_empty());
    }

    #[test]
    fn float_ord_flags_partial_cmp() {
        let src = "fn f(xs: &mut [f64]) { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        assert_eq!(lints(&scan_source("model/foo.rs", src)), ["det-float-ord"]);
        let ok = "fn f(xs: &mut [f64]) { xs.sort_by(|a, b| a.total_cmp(b)); }\n";
        assert!(scan_source("model/foo.rs", ok).is_empty());
    }

    #[test]
    fn rand_tokens_flagged_in_scope() {
        let src = "fn f() { let r = thread_rng(); }\n";
        assert_eq!(lints(&scan_source("shedding/foo.rs", src)), ["det-rand"]);
    }

    #[test]
    fn clock_wall_everywhere_but_the_clock_plane() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        assert_eq!(lints(&scan_source("harness/foo.rs", src)), ["clock-wall"]);
        assert!(scan_source("sim/clock.rs", src).is_empty());
        let st = "fn f() { let t = std::time::SystemTime::now(); }\n";
        assert_eq!(lints(&scan_source("harness/foo.rs", st)), ["clock-wall"]);
    }

    #[test]
    fn instant_now_split_across_whitespace_still_caught() {
        let src = "fn f() { let t = Instant::  now(); }\n";
        assert_eq!(lints(&scan_source("harness/foo.rs", src)), ["clock-wall"]);
        // a method named now() on something else is not a wall read
        let other = "fn f(c: &C) { let t = c.now(); }\n";
        assert!(scan_source("harness/foo.rs", other).is_empty());
    }

    #[test]
    fn panic_path_only_in_sharded_runtime() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert_eq!(lints(&scan_source("runtime/sharded/foo.rs", src)), ["panic-path"]);
        assert!(scan_source("operator/foo.rs", src).is_empty());
        let mac = "fn f() { unreachable!(\"nope\") }\n";
        assert_eq!(lints(&scan_source("runtime/sharded/foo.rs", mac)), ["panic-path"]);
    }

    #[test]
    fn unwrap_without_call_parens_is_not_flagged() {
        // e.g. unwrap_or_default, a field called unwrap, docs
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap_or_default() }\n";
        assert!(scan_source("runtime/sharded/foo.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_regions_are_skipped() {
        let src = "\
fn live() {}\n\
#[cfg(test)]\n\
mod tests {\n\
    use std::collections::HashMap;\n\
    #[test]\n\
    fn t() { let _ = std::time::Instant::now(); }\n\
}\n";
        assert!(scan_source("operator/foo.rs", src).is_empty());
    }

    #[test]
    fn comments_and_strings_never_fire() {
        let src = "\
// HashMap iteration would be bad here; Instant::now too\n\
/* block comment: partial_cmp */\n\
fn f() -> &'static str { \"HashMap Instant::now partial_cmp\" }\n";
        assert!(scan_source("operator/foo.rs", src).is_empty());
    }

    #[test]
    fn identifier_boundaries_respected() {
        // MyHashMapLike / hash_map_ish must not match
        let src = "struct MyHashMapLike; fn f(x: MyHashMapLike) {}\n";
        assert!(scan_source("operator/foo.rs", src).is_empty());
    }

    #[test]
    fn allow_with_reason_suppresses() {
        let src = "\
fn f() {\n\
    // audit:allow(wall-clock): instrumentation only\n\
    let t = std::time::Instant::now();\n\
}\n";
        assert!(scan_source("harness/foo.rs", src).is_empty());
    }

    #[test]
    fn allow_in_wrapped_comment_block_suppresses() {
        let src = "\
fn f() {\n\
    // audit:allow(wall-clock): a long reason that wraps\n\
    // onto a second comment line before the code\n\
    let t = std::time::Instant::now();\n\
}\n";
        assert!(scan_source("harness/foo.rs", src).is_empty());
    }

    #[test]
    fn allow_without_reason_is_bad_suppression() {
        let src = "\
fn f() {\n\
    // audit:allow(wall-clock)\n\
    let t = std::time::Instant::now();\n\
}\n";
        assert_eq!(lints(&scan_source("harness/foo.rs", src)), ["bad-suppression"]);
    }

    #[test]
    fn allow_for_the_wrong_key_does_not_suppress() {
        let src = "\
fn f() {\n\
    // audit:allow(panic): wrong key\n\
    let t = std::time::Instant::now();\n\
}\n";
        assert_eq!(lints(&scan_source("harness/foo.rs", src)), ["clock-wall"]);
    }

    #[test]
    fn allow_does_not_leak_past_code_lines() {
        let src = "\
fn f() {\n\
    // audit:allow(wall-clock): covers only the next line\n\
    let a = std::time::Instant::now();\n\
    let b = std::time::Instant::now();\n\
}\n";
        let f = scan_source("harness/foo.rs", src);
        assert_eq!(lints(&f), ["clock-wall"]);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn no_alloc_marker_bans_allocation_in_the_fn_body() {
        let src = "\
// audit: no-alloc\n\
fn hot(xs: &[u32], out: &mut Vec<u32>) {\n\
    let v: Vec<u32> = xs.iter().copied().collect();\n\
    out.push(v.len() as u32);\n\
}\n\
fn cold(xs: &[u32]) -> Vec<u32> { xs.to_vec() }\n";
        let f = scan_source("util/foo.rs", src);
        assert_eq!(lints(&f), ["alloc-hot"], "collect flagged; cold fn untouched");
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn no_alloc_allows_push_and_mem_take() {
        let src = "\
// audit: no-alloc\n\
fn hot(out: &mut Vec<u32>, buf: &mut Vec<u32>) {\n\
    let mut scratch = std::mem::take(buf);\n\
    scratch.sort_unstable_by(|a, b| a.cmp(b));\n\
    out.push(1);\n\
    *buf = scratch;\n\
}\n";
        assert!(scan_source("util/foo.rs", src).is_empty());
    }

    #[test]
    fn no_alloc_respects_allow_annotations() {
        let src = "\
// audit: no-alloc\n\
fn hot(xs: &[u32]) {\n\
    // audit:allow(alloc): cold fallback path, measured on purpose\n\
    let v = xs.to_vec();\n\
    drop(v);\n\
}\n";
        assert!(scan_source("util/foo.rs", src).is_empty());
    }

    #[test]
    fn collect_as_plain_word_not_flagged() {
        let src = "\
// audit: no-alloc\n\
fn hot(collector: &mut u32) {\n\
    *collector += 1;\n\
}\n";
        assert!(scan_source("util/foo.rs", src).is_empty());
    }

    #[test]
    fn baseline_roundtrip_and_filtering() {
        let empty = parse_baseline("[]\n").unwrap();
        assert!(empty.is_empty());
        let keys = parse_baseline("[\n  \"operator/foo.rs:det-hash\"\n]").unwrap();
        assert_eq!(keys, ["operator/foo.rs:det-hash"]);
        let findings = scan_source("operator/foo.rs", "use std::collections::HashMap;\n");
        assert_eq!(findings.len(), 1);
        assert!(apply_baseline(findings.clone(), &keys).is_empty());
        assert_eq!(apply_baseline(findings, &empty).len(), 1);
        assert!(parse_baseline("{\"not\": \"an array\"}").is_err());
    }

    #[test]
    fn json_output_is_well_formed() {
        let f = scan_source("operator/foo.rs", "use std::collections::HashMap;\n");
        let j = to_json(&f);
        assert!(j.contains("\"count\": 1"));
        assert!(j.contains("\"lint\": \"det-hash\""));
        assert!(j.contains("\"file\": \"operator/foo.rs\""));
        assert_eq!(to_json(&[]), "{\n  \"count\": 0,\n  \"findings\": []\n}\n");
    }

    #[test]
    fn raw_strings_and_chars_are_blanked() {
        let src = "fn f() { let s = r#\"HashMap \"quoted\" partial_cmp\"#; \
                   let c = '\\n'; let l: &'static str = s; }\n";
        assert!(scan_source("operator/foo.rs", src).is_empty());
    }

    #[test]
    fn finding_key_excludes_line_numbers() {
        let f = scan_source("operator/foo.rs", "\n\nuse std::collections::HashMap;\n");
        assert_eq!(f[0].line, 3);
        assert_eq!(f[0].key(), "operator/foo.rs:det-hash");
    }
}
