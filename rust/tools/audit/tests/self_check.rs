//! Self-tests wiring the auditor to the real repository:
//!
//! 1. the committed baseline is empty and stays empty;
//! 2. the live `rust/src` tree scans clean (zero unsuppressed
//!    findings) — this runs under plain `cargo test`, so the invariant
//!    gate fires in tier-1 CI, not just in the dedicated job;
//! 3. an injected violation in a synthetic tree *is* caught, and the
//!    binary exits non-zero on it — proof the CI gate fails red rather
//!    than silently passing.

use std::fs;
use std::path::PathBuf;
use std::process::Command;

use pallas_audit::{parse_baseline, scan_tree};

fn repo_src() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../src")
}

fn baseline_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("baseline.json")
}

#[test]
fn committed_baseline_is_empty() {
    let text = fs::read_to_string(baseline_path()).expect("baseline.json must exist");
    let keys = parse_baseline(&text).expect("baseline.json must parse");
    assert!(
        keys.is_empty(),
        "the committed baseline must stay empty: fix or annotate findings \
         instead of baselining them (found {keys:?})"
    );
}

#[test]
fn repository_scans_clean() {
    let findings = scan_tree(&repo_src()).expect("rust/src must be readable");
    assert!(
        findings.is_empty(),
        "rust/src must have zero unsuppressed audit findings; either fix the \
         code or add an `// audit:allow(<key>): <reason>` annotation:\n{}",
        findings
            .iter()
            .map(|f| format!("  {f}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// A scratch tree under the target dir (unique per test so parallel
/// runs don't collide), cleaned up on drop.
struct ScratchTree(PathBuf);

impl ScratchTree {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!(
            "pallas-audit-selftest-{}-{tag}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(dir.join("operator")).expect("create scratch tree");
        ScratchTree(dir)
    }
}

impl Drop for ScratchTree {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

#[test]
fn injected_hash_violation_is_caught() {
    let tree = ScratchTree::new("lib");
    fs::write(
        tree.0.join("operator/fresh.rs"),
        "use std::collections::HashMap;\n\
         pub fn f() -> HashMap<u32, u32> { HashMap::new() }\n",
    )
    .expect("write injected violation");
    // an innocent file next to it stays clean
    fs::write(
        tree.0.join("operator/clean.rs"),
        "pub fn g(xs: &mut Vec<u32>) { xs.sort_unstable(); }\n",
    )
    .expect("write clean file");

    let findings = scan_tree(&tree.0).expect("scan scratch tree");
    assert!(
        !findings.is_empty(),
        "a fresh HashMap in operator/ must be flagged"
    );
    assert!(findings.iter().all(|f| f.lint.id() == "det-hash"));
    assert!(findings.iter().all(|f| f.file == "operator/fresh.rs"));
}

#[test]
fn binary_fails_red_on_injected_violation() {
    let tree = ScratchTree::new("bin");
    fs::write(
        tree.0.join("operator/fresh.rs"),
        "use std::collections::HashMap;\n",
    )
    .expect("write injected violation");

    let out = Command::new(env!("CARGO_BIN_EXE_pallas-audit"))
        .args(["--root"])
        .arg(&tree.0)
        .arg("--json")
        .output()
        .expect("run pallas-audit binary");
    assert_eq!(
        out.status.code(),
        Some(1),
        "findings must exit 1 (stdout: {})",
        String::from_utf8_lossy(&out.stdout)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"lint\": \"det-hash\""));
    assert!(stdout.contains("operator/fresh.rs"));
}

#[test]
fn binary_scans_the_real_tree_clean() {
    let out = Command::new(env!("CARGO_BIN_EXE_pallas-audit"))
        .args(["--root"])
        .arg(repo_src())
        .args(["--baseline"])
        .arg(baseline_path())
        .output()
        .expect("run pallas-audit binary");
    assert_eq!(
        out.status.code(),
        Some(0),
        "rust/src must scan clean through the CLI (stdout: {})",
        String::from_utf8_lossy(&out.stdout)
    );
}

#[test]
fn suppression_without_reason_fails_the_scan() {
    let tree = ScratchTree::new("supp");
    fs::write(
        tree.0.join("operator/lazy.rs"),
        "// audit:allow(hash-iter)\n\
         use std::collections::HashSet;\n",
    )
    .expect("write reasonless suppression");
    let findings = scan_tree(&tree.0).expect("scan scratch tree");
    assert_eq!(findings.len(), 1);
    assert_eq!(findings[0].lint.id(), "bad-suppression");
}
