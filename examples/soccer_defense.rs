//! Soccer defense analytics (the paper's Q3 scenario, DEBS'13-style
//! RTLS data).
//!
//! ```text
//! cargo run --release --example soccer_defense
//! ```
//!
//! Q3 detects `seq(STR; any(n, DF…))`: a striker takes possession and
//! `n` distinct opposing players close in within a 1.5 s time window.
//! The example sweeps the pattern size `n` (which controls the match
//! probability, exactly like the paper's Fig. 5c sweep) and compares
//! pSPICE against both baselines at 120% overload.

use pspice::config::ExperimentConfig;
use pspice::datasets::DatasetKind;
use pspice::harness::run_experiment;
use pspice::shedding::ShedderKind;

fn main() -> pspice::Result<()> {
    pspice::util::logger::init();
    println!("soccer defense monitor (Q3), 120% overload, LB=0.5ms\n");
    println!(
        "{:>3} | {:>7} | {:>16} | {:>16} | {:>16}",
        "n", "match_p", "pspice fn%", "pm-bl fn%", "e-bl fn%"
    );
    for n in [6, 4, 3, 2] {
        let mut line = format!("{n:>3} | ");
        let mut mp = 0.0;
        for (i, shedder) in [
            ShedderKind::PSpice,
            ShedderKind::PmBaseline,
            ShedderKind::EventBaseline,
        ]
        .iter()
        .enumerate()
        {
            let cfg = ExperimentConfig {
                query: "q3".into(),
                window: 1_500, // ms
                pattern_n: n,
                dataset: DatasetKind::Soccer,
                seed: 23,
                warmup: 60_000,
                events: 60_000,
                rate: 1.2,
                lb_ms: 0.5,
                shedder: *shedder,
                ..ExperimentConfig::default()
            };
            let r = run_experiment(&cfg)?;
            mp = r.match_probability;
            if i == 0 {
                line = format!("{n:>3} | {:>6.2}% | ", mp * 100.0);
            }
            line.push_str(&format!("{:>15.2}% | ", r.fn_percent));
        }
        println!("{}", line.trim_end_matches(" | "));
        let _ = mp;
    }
    println!("\nsmaller patterns complete more often (higher match probability),");
    println!("which squeezes every shedder — but informed PM dropping degrades least.");
    Ok(())
}
