//! Stock-market monitoring (the paper's Q1/Q2 scenario).
//!
//! ```text
//! cargo run --release --example stock_market
//! ```
//!
//! An operator watches an NYSE-like quote stream for ordered
//! rising/falling runs across ten symbols (Q1) and the repetition
//! pattern (Q2) *simultaneously* (a multi-query operator), with Q2
//! declared twice as important (pattern weights, paper §II-B).  The
//! example sweeps the input rate and prints how the weighted QoR
//! degrades gracefully under increasing overload.

use pspice::config::ExperimentConfig;
use pspice::datasets::DatasetKind;
use pspice::harness::run_experiment;
use pspice::shedding::ShedderKind;

fn main() -> pspice::Result<()> {
    pspice::util::logger::init();
    println!("multi-query stock monitor: Q1 (w=1) + Q2 (w=2), pSPICE\n");
    println!("{:>6} | {:>8} | {:>7} | {:>9} | {:>10}", "rate", "fn_w%", "fp", "drops", "max_lat_ms");
    for rate in [1.0, 1.2, 1.5, 2.0] {
        let cfg = ExperimentConfig {
            query: "q1+q2".into(),
            window: 6_000,
            pattern_n: 0,
            dataset: DatasetKind::Stock,
            seed: 11,
            warmup: 50_000,
            events: 50_000,
            rate,
            // wide enough that shedding is driven by the rate, not by
            // the bound alone (see EXPERIMENTS.md Fig. 8 note)
            lb_ms: 2.5,
            shedder: ShedderKind::PSpice,
            // [q1_rise, q1_fall, q2_rise, q2_fall]
            weights: vec![1.0, 1.0, 2.0, 2.0],
            ..ExperimentConfig::default()
        };
        let r = run_experiment(&cfg)?;
        println!(
            "{:>5.0}% | {:>7.2}% | {:>7} | {:>9} | {:>10.3}",
            rate * 100.0,
            r.fn_percent,
            r.false_positives,
            r.dropped_pms,
            r.latency.stats.max() / 1e6
        );
    }
    println!("\nhigher overload -> more PMs shed -> higher weighted FN%, but the");
    println!("latency bound holds at every rate and no false positives appear.");
    Ok(())
}
