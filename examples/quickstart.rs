//! Quickstart: the canonical walkthrough of the `Pipeline` builder
//! API — calibrate once, then run any shedding strategy on any
//! backend through one façade.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a synthetic Dublin-style bus trace, calibrates the
//! overload detector and trains the Markov utility model (through the
//! AOT/PJRT artifacts if `make artifacts` has run, otherwise the rust
//! fallback), then overloads the operator at 140% of its measured
//! capacity and shows pSPICE holding the latency bound while dropping
//! far less quality than random PM shedding.  Later sections embed
//! the same engine incrementally via `Pipeline::feed`, retrain the
//! model plane on drift, drive the real-time ingestion plane from
//! a synthetic burst source through the bounded ingest queue, pin
//! the scorecard's run-manifest identity for the gated evaluation
//! grid, kill shard workers mid-run with a deterministic
//! `FaultPlan` to show shed-native recovery, and finally arm the
//! checkpoint plane so the same kills recover *losslessly* via
//! snapshot + journal replay.

use pspice::datasets::{BusGen, DatasetKind};
use pspice::events::EventStream;
use pspice::ingest::{Burst, OverflowPolicy, SyntheticSource};
use pspice::model::{ModelBuilder, ModelConfig, ModelKind};
use pspice::operator::Operator;
use pspice::pipeline::Pipeline;
use pspice::query::builtin::q4;
use pspice::runtime::FaultPlan;
use pspice::shedding::{OverloadDetector, ShedderKind};
use pspice::sim::RateSource;

const LB_MS: f64 = 0.5; // latency bound (virtual ms)
const RATE: f64 = 1.4; // 140% of measured capacity

fn main() -> pspice::Result<()> {
    pspice::util::logger::init();
    println!("pSPICE quickstart — Q4 (bus delays), 140% overload\n");

    // Q4: any(4) distinct delayed buses at the same stop, count
    // window 2000, slide 250 — and a seeded synthetic trace
    let queries = q4(4, 2_000, 250).queries;
    let trace = BusGen::with_seed(7).take_events(80_000);
    let (warm, measure) = trace.split_at(40_000);

    // 1. calibrate: stream the warm-up below capacity on a plain
    //    operator, fit the latency regressions f()/g() (paper Alg. 1),
    //    and build the utility model from its observations
    let lb_ns = LB_MS * 1e6;
    let mut op = Operator::new(queries.clone());
    let mut detector = OverloadDetector::new(lb_ns, 0.02 * lb_ns);
    let mut capacity_ns = 0.0;
    for e in warm {
        let n_before = op.pm_count();
        let out = op.process_event(e);
        detector.observe_processing(n_before, out.cost_ns);
        capacity_ns += out.cost_ns;
    }
    capacity_ns /= warm.len() as f64;
    assert!(detector.fit(), "latency regression needs more warm-up");
    for n in [100usize, 1_000, 5_000, 20_000] {
        // the shed-decision scan is priced per *cell*: convert the
        // seeded PM populations through the mean cell occupancy
        let cells = (n as f64 / pspice::operator::EST_PMS_PER_CELL) as usize;
        detector.observe_shedding(n, op.cost.shed_ns(cells, n / 10));
    }
    detector.fit();
    let mut builder = ModelBuilder::with_auto_engine(ModelConfig::default());
    let tables = builder.build(&op)?;
    println!(
        "calibrated: capacity={capacity_ns:.0} ns/event, model via {}\n",
        builder.engine_name()
    );

    // 2. the builder façade: same calibration, three strategies —
    //    swap `.shards(1)` for `.shards(4)` and nothing else changes
    for kind in [ShedderKind::PSpice, ShedderKind::PmBaseline, ShedderKind::None] {
        let mut pipe = Pipeline::builder()
            .queries(queries.clone())
            .shedder(kind)
            .detector(detector.clone())
            .tables(tables.clone())
            .latency_bound_ms(LB_MS)
            .shards(1)
            .batch(256)
            .seed(7)
            .key_slot(DatasetKind::Bus.key_slot())
            .arrivals(RateSource::from_capacity(capacity_ns, RATE, 0.0))
            .source(measure.to_vec())
            .build()?;
        pipe.prime(warm);
        let run = pipe.run_to_end()?;
        println!(
            "{:<8} dropped_pms={:<6} max_latency={:>8.3}ms  violations={:>6.2}%  \
             overhead={:.3}%",
            run.shedder,
            run.totals.dropped_pms,
            run.latency.stats.max() / 1e6,
            run.latency.violation_rate() * 100.0,
            run.shed_overhead * 100.0,
        );
    }
    println!(
        "\npSPICE keeps the latency bound with far fewer drops than random \
         PM shedding; without shedding the bound is violated."
    );

    // 3. embedding: feed() event slices as they arrive instead of
    //    handing the pipeline a whole trace
    let mut pipe = Pipeline::builder()
        .queries(queries.clone())
        .shedder(ShedderKind::PSpice)
        .detector(detector.clone())
        .tables(tables.clone())
        .latency_bound_ms(LB_MS)
        .arrivals(RateSource::from_capacity(capacity_ns, RATE, 0.0))
        .build()?;
    pipe.prime(warm);
    let mut detected = 0usize;
    for chunk in measure.chunks(1_000) {
        detected += pipe.feed(chunk)?.len();
    }
    println!(
        "\nincremental feed: {detected} complex events, {} PMs shed, {} PMs live",
        pipe.totals().dropped_pms,
        pipe.pm_count()
    );

    // 4. the versioned model plane: drift-triggered retraining publishes
    //    fresh epoch-numbered TableSets on ANY backend (shards > 1
    //    broadcasts them to every worker), and `.model(..)` swaps the
    //    UtilityModel backend — here the frequency-only predictor
    let mut pipe = Pipeline::builder()
        .queries(queries.clone())
        .shedder(ShedderKind::PSpice)
        .detector(detector.clone())
        .model(ModelKind::Freq)
        .retrain(10_000, 1e-9) // tight threshold: retrain eagerly
        .latency_bound_ms(LB_MS)
        .arrivals(RateSource::from_capacity(capacity_ns, RATE, 0.0))
        .build()?;
    pipe.prime(warm);
    pipe.feed(measure)?;
    let run = pipe.summary(Vec::new());
    println!(
        "\nmodel plane: {} retrains -> table epoch {} (freq backend)",
        run.retrains,
        pipe.table_epoch()
    );

    // 5. the real-time ingestion plane: a synthetic burst source feeds
    //    the bounded ingest queue and `run_realtime` drives the loop on
    //    the clock abstraction — swap `.wall_clock()` into the builder
    //    and the identical code runs against real time
    let period_ns = 2_000.0 * capacity_ns;
    let source = SyntheticSource::new(
        measure.to_vec(),
        Box::new(Burst::from_capacity(
            capacity_ns,
            0.5,        // quiet phase: 50% of capacity
            2.0 * RATE, // bursts: 280% of capacity
            period_ns,
            0.25 * period_ns,
        )),
        measure[0].seq,
        warm.last().map_or(0.0, |e| e.ts_ms as f64 * 1e6),
    )
    .with_limit(20_000);
    let mut pipe = Pipeline::builder()
        .queries(queries)
        .shedder(ShedderKind::PSpice)
        .detector(detector.clone())
        .tables(tables)
        .latency_bound_ms(LB_MS)
        .key_slot(DatasetKind::Bus.key_slot())
        .ingest_source(Box::new(source))
        .ingest_capacity(4_096)
        .ingest_policy(OverflowPolicy::DropOldest)
        .build()?;
    pipe.prime(warm);
    let run = pipe.run_realtime(f64::INFINITY)?;
    println!(
        "\nreal-time burst ingest: p95={:.3}ms (LB={LB_MS}ms), {} PMs shed, \
         {} events lost at the queue",
        run.latency.p95_ns() / 1e6,
        run.totals.dropped_pms,
        run.queue_dropped,
    );

    // 6. the scorecard: the same measurements, as a gated protocol.
    //    A RunManifest pins every input under a content hash — same
    //    hash, same primary metrics (bit-identical under the sim
    //    clock) — and `cargo run --release -- scoreboard --smoke`
    //    runs the full strategy x dataset grid, appends a line to the
    //    committed SCORECARD.jsonl, and fails on any >5% regression
    //    against the previous comparable entry.  Here: just the
    //    manifest identity for the smoke grid.
    let sc = pspice::config::ScorecardConfig::default();
    let manifest = pspice::scorecard::RunManifest {
        smoke: true,
        commit: pspice::scorecard::manifest::git_commit(),
        seeds: (0..sc.reps as u64).map(|r| sc.base_seed + r).collect(),
        sc,
        cells: pspice::scorecard::grid(true),
    };
    println!(
        "\nscorecard: {} grid cells x {} seeds pinned as {} \
         (run `scoreboard --smoke` for the gated protocol)",
        manifest.cells.len(),
        manifest.seeds.len(),
        manifest.hash(),
    );

    // 7. chaos: a deterministic FaultPlan kills both shard workers
    //    mid-run.  The coordinator detects each death, respawns the
    //    worker with the current table epoch, and books the partial
    //    matches that died with it as an involuntary shed round
    //    (`dropped_pms_failure`) — failure costs result quality, never
    //    the latency bound.  Dispatch counts are cumulative from
    //    priming: 40k warm events / batch 256 = ~157 dispatches, so
    //    170/190 land in the overloaded measurement phase.  Same spec
    //    on the CLI: `realtime ... --faults kill:0@170,kill:1@190`.
    let two_queries = {
        // two Q4 variants (slide 250 vs 500), one shard each
        let mut v = q4(4, 2_000, 250).queries;
        v.extend(q4(4, 2_000, 500).queries);
        v
    };
    let source = SyntheticSource::new(
        measure.to_vec(),
        Box::new(Burst::from_capacity(
            capacity_ns,
            0.5,
            2.0 * RATE,
            period_ns,
            0.25 * period_ns,
        )),
        measure[0].seq,
        warm.last().map_or(0.0, |e| e.ts_ms as f64 * 1e6),
    )
    .with_limit(12_000);
    let mut pipe = Pipeline::builder()
        .queries(two_queries)
        .shedder(ShedderKind::PSpice)
        .detector(detector.clone())
        .model(ModelKind::Freq)
        .retrain(10_000, 1e-9)
        .latency_bound_ms(LB_MS)
        .shards(2)
        .batch(256)
        .seed(7)
        .key_slot(DatasetKind::Bus.key_slot())
        .fault_plan(FaultPlan::parse("kill:0@170,kill:1@190")?)
        .ingest_source(Box::new(source))
        .build()?;
    pipe.prime(warm);
    let run = pipe.run_realtime(f64::INFINITY)?;
    println!(
        "\nchaos: {} worker deaths survived, {} PMs lost to crashes \
         (counted as shed), p95={:.3}ms (LB={LB_MS}ms)",
        run.recoveries,
        run.totals.dropped_pms_failure,
        run.latency.p95_ns() / 1e6,
    );

    // 8. checkpointed chaos: the same kills, lossless.  With
    //    `.checkpoint_every(8)` each shard snapshots its full state
    //    every 8 dispatches and the coordinator journals dispatches
    //    (up to `.journal_cap(..)` buffered events) since the last
    //    ack; a respawn restores the snapshot and replays the journal
    //    tail, so the PMs that died come back as `recovered_pms`
    //    instead of being booked to `dropped_pms_failure`.  Replay
    //    cost is charged to the clock — lossless recovery pays in
    //    catch-up latency what lossy recovery pays in quality.  Same
    //    knobs on the CLI: `realtime ... --checkpoint-every 8
    //    --journal-cap 20000` (and `--deadline-ms F` arms hang
    //    detection on the dispatch path; wall-clock runs derive a
    //    default deadline from the latency bound automatically).
    let two_queries = {
        let mut v = q4(4, 2_000, 250).queries;
        v.extend(q4(4, 2_000, 500).queries);
        v
    };
    let source = SyntheticSource::new(
        measure.to_vec(),
        Box::new(Burst::from_capacity(
            capacity_ns,
            0.5,
            2.0 * RATE,
            period_ns,
            0.25 * period_ns,
        )),
        measure[0].seq,
        warm.last().map_or(0.0, |e| e.ts_ms as f64 * 1e6),
    )
    .with_limit(12_000);
    let mut pipe = Pipeline::builder()
        .queries(two_queries)
        .shedder(ShedderKind::PSpice)
        .detector(detector)
        .model(ModelKind::Freq)
        .retrain(10_000, 1e-9)
        .latency_bound_ms(LB_MS)
        .shards(2)
        .batch(256)
        .seed(7)
        .key_slot(DatasetKind::Bus.key_slot())
        .fault_plan(FaultPlan::parse("kill:0@170,kill:1@190")?)
        .checkpoint_every(8)
        .journal_cap(20_000)
        .ingest_source(Box::new(source))
        .build()?;
    pipe.prime(warm);
    let run = pipe.run_realtime(f64::INFINITY)?;
    println!(
        "\ncheckpointed chaos: {} deaths recovered losslessly — {} PMs \
         restored ({} events replayed), {} lost to crashes, p95={:.3}ms",
        run.recoveries,
        run.totals.recovered_pms,
        run.totals.replayed_events,
        run.totals.dropped_pms_failure,
        run.latency.p95_ns() / 1e6,
    );

    // 9. the invariant audit: everything above is bit-exact — same
    //    trace + seed, same bytes out, across shard counts and
    //    recovery paths.  `pallas-audit` (rust/tools/audit) is the
    //    static gate that keeps it that way: a token-level scan of
    //    rust/src banning hash-container iteration / `partial_cmp` /
    //    unseeded randomness in result-affecting modules, wall-clock
    //    reads outside the sim::Clock plane, panics on the sharded
    //    supervision paths, and allocation in `// audit: no-alloc`
    //    hot functions.  Run it locally:
    //
    //        cargo run -p pallas-audit
    //        cargo run -p pallas-audit -- --json
    //
    //    Exit 0 means clean; findings exit 1 with file:line, and CI's
    //    `static-audit` job holds the committed baseline at empty.
    //    Deliberate exceptions are annotated in source as
    //    `// audit:allow(<key>): <reason>` — a missing reason is
    //    itself a finding.  (See EXPERIMENTS.md design note #8.)
    println!(
        "\ninvariant audit: `cargo run -p pallas-audit` scans rust/src \
         for determinism/clock/panic/alloc violations (CI: static-audit)"
    );
    Ok(())
}
