//! Quickstart: the whole pSPICE pipeline on one small workload.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a synthetic Dublin-style bus trace, builds the ground
//! truth, trains the Markov utility model (through the AOT/PJRT
//! artifacts if `make artifacts` has run, otherwise the rust fallback),
//! then overloads the operator at 140% of its measured capacity and
//! shows pSPICE holding a latency bound while keeping the false
//! negatives far below random shedding.

use pspice::config::ExperimentConfig;
use pspice::datasets::DatasetKind;
use pspice::harness::run_experiment;
use pspice::shedding::ShedderKind;

fn main() -> pspice::Result<()> {
    pspice::util::logger::init();

    let base = ExperimentConfig {
        query: "q4".into(),       // any(n) over same-stop bus delays
        window: 2_000,            // count window
        pattern_n: 4,             // 4 distinct delayed buses
        slide: 250,
        dataset: DatasetKind::Bus,
        seed: 7,
        warmup: 40_000,
        events: 40_000,
        rate: 1.4,                // 140% of capacity
        lb_ms: 0.5,               // latency bound (virtual ms)
        shedder: ShedderKind::PSpice,
        weights: Vec::new(),
        cost_factors: Vec::new(),
        retrain_every: 0,
        drift_threshold: 0.01,
        shards: 1,
        batch: 256,
    };

    println!("pSPICE quickstart — Q4 (bus delays), 140% overload\n");
    for shedder in [ShedderKind::PSpice, ShedderKind::PmBaseline, ShedderKind::None] {
        let cfg = ExperimentConfig {
            shedder,
            ..base.clone()
        };
        let r = run_experiment(&cfg)?;
        println!(
            "{:<8} fn={:>5.1}%  fp={}  max_latency={:>8.3}ms  violations={:>6.2}%  \
             dropped_pms={:<6} engine={}",
            r.shedder,
            r.fn_percent,
            r.false_positives,
            r.latency.stats.max() / 1e6,
            r.latency.violation_rate() * 100.0,
            r.dropped_pms,
            r.engine,
        );
    }
    println!(
        "\npSPICE keeps the latency bound with fewer false negatives than \
         random PM shedding; without shedding the bound is violated."
    );
    Ok(())
}
