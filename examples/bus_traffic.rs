//! End-to-end driver (DESIGN.md: the full-system validation example).
//!
//! ```text
//! cargo run --release --example bus_traffic
//! ```
//!
//! Exercises **every layer** of the stack on one realistic workload and
//! reports the paper's headline metrics:
//!
//! 1. generates a Dublin-style bus trace and archives it to CSV
//!    (datasets + replay),
//! 2. parses Q4 from the text DSL (query front-end),
//! 3. runs the ground truth + calibration + overloaded phases through
//!    the `Pipeline`-backed harness (operator state, overload detector
//!    and the batch-first pSPICE shedder — L3; see
//!    `examples/quickstart.rs` for driving the builder API directly),
//! 4. builds the utility model through the **AOT HLO artifacts on the
//!    PJRT runtime** (L2/L1) — this is the rust⇄XLA boundary —
//!    falling back to the rust engine only if artifacts are missing,
//! 5. cross-checks the PJRT-built utility tables against the pure-rust
//!    oracle, and
//! 6. prints the paper-style summary: FN% vs baselines, latency-bound
//!    compliance, shedding overhead, and model-build cost.

use pspice::config::ExperimentConfig;
use pspice::datasets::{csv, BusGen, DatasetKind};
use pspice::events::EventStream;
use pspice::harness::run_experiment;
use pspice::model::{ModelBuilder, ModelConfig};
use pspice::operator::Operator;
use pspice::query::parse_query;
use pspice::runtime::FallbackEngine;
use pspice::shedding::ShedderKind;

fn main() -> pspice::Result<()> {
    pspice::util::logger::init();
    println!("=== pSPICE end-to-end driver: Dublin bus traffic (Q4) ===\n");

    // 1. data layer: generate + archive + replay
    let mut gen = BusGen::with_seed(99);
    let events = gen.take_events(20_000);
    let path = std::env::temp_dir().join("pspice_bus_trace.csv");
    csv::write_csv(&path, &events)?;
    let replay = csv::read_csv(&path)?;
    assert_eq!(events, replay);
    println!(
        "[1] trace: {} events archived to {} and replayed byte-identically",
        events.len(),
        path.display()
    );

    // 2. query front-end: Q4 from the text DSL
    let schema = pspice::query::builtin::schema_for("q4");
    let q = parse_query(
        "query q4_dsl {
           window count 2000
           open every 250
           any 4 of bus where delayed == 1 && stop == key(0) bind key(0) = stop
             distinct bus
         }",
        &schema,
    )?;
    println!(
        "[2] DSL query {:?}: {} Markov states, window {:?}",
        q.name,
        q.state_count(),
        q.window
    );

    // 3.+4. the full pipeline under 140% overload
    let cfg = ExperimentConfig {
        query: "q4".into(),
        window: 2_000,
        pattern_n: 4,
        slide: 250,
        dataset: DatasetKind::Bus,
        seed: 99,
        warmup: 50_000,
        events: 50_000,
        rate: 1.4,
        lb_ms: 0.5,
        shedder: ShedderKind::PSpice,
        ..ExperimentConfig::default()
    };
    let pspice = run_experiment(&cfg)?;
    let pm_bl = run_experiment(&ExperimentConfig {
        shedder: ShedderKind::PmBaseline,
        ..cfg.clone()
    })?;
    let e_bl = run_experiment(&ExperimentConfig {
        shedder: ShedderKind::EventBaseline,
        ..cfg.clone()
    })?;
    println!(
        "[3] overloaded run (140%): capacity={:.0} ns/event, ground truth={} CEs, \
         match probability={:.1}%",
        pspice.capacity_ns,
        pspice.truth_total,
        pspice.match_probability * 100.0
    );
    println!("[4] model engine on the request path: {}", pspice.engine);

    // 5. differential check: PJRT/auto engine vs pure-rust oracle
    let mut op = Operator::new(pspice::query::builtin::q4(4, 2_000, 250).queries);
    let mut g2 = BusGen::with_seed(99);
    for _ in 0..30_000 {
        op.process_event(&g2.next_event().unwrap());
    }
    let mut auto = ModelBuilder::with_auto_engine(ModelConfig::default());
    let mut fall = ModelBuilder::new(ModelConfig::default(), Box::new(FallbackEngine));
    let ta = auto.build(&op)?;
    let tf = fall.build(&op)?;
    let mut max_diff = 0.0f64;
    for (a, f) in ta[0].rows.iter().zip(&tf[0].rows) {
        for (x, y) in a.iter().zip(f) {
            max_diff = max_diff.max((x - y).abs());
        }
    }
    println!(
        "[5] utility tables: {} vs rust oracle, max |Δ| = {max_diff:.2e}",
        auto.engine_name()
    );
    assert!(max_diff < 1e-3, "engines disagree");

    // 6. headline table
    println!("\n=== headline (paper shape: pSPICE < PM-BL, low overhead) ===");
    println!(
        "{:<8} {:>7} {:>5} {:>12} {:>12} {:>10}",
        "shedder", "fn%", "fp", "max_lat_ms", "violations%", "overhead%"
    );
    for r in [&pspice, &pm_bl, &e_bl] {
        println!(
            "{:<8} {:>6.2}% {:>5} {:>12.3} {:>11.2}% {:>9.3}%",
            r.shedder,
            r.fn_percent,
            r.false_positives,
            r.latency.stats.max() / 1e6,
            r.latency.violation_rate() * 100.0,
            r.shed_overhead * 100.0
        );
    }
    println!(
        "\nmodel build: {:.4}s via {} (paper Fig. 9b scale: seconds)",
        pspice.model_build_secs, pspice.engine
    );
    Ok(())
}
