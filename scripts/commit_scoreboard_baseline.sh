#!/usr/bin/env bash
# Append CI's full-scale scoreboard ledger line to the committed
# SCORECARD.jsonl — the one maintainer step the scoreboard-full job
# cannot do itself (a CI bot must not write the append-only ledger;
# see .github/workflows/ci.yml and EXPERIMENTS.md design note #5).
#
# Usage:
#   scripts/commit_scoreboard_baseline.sh full_scorecard_line.jsonl
#
# where the argument is the `scoreboard-full-line` artifact downloaded
# from a green `scoreboard-full` CI run on the commit being blessed.
# The script validates the line (schema tag, non-smoke, single line,
# parseable JSON, manifest hash present), refuses duplicates, appends
# it, and leaves the git commit to the maintainer.
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
ledger="$repo_root/SCORECARD.jsonl"
line_file="${1:?usage: $0 <full_scorecard_line.jsonl>}"

[ -f "$line_file" ] || { echo "error: $line_file not found" >&2; exit 1; }

lines=$(wc -l < "$line_file")
if [ "$lines" -ne 1 ]; then
    echo "error: expected exactly 1 ledger line in $line_file, got $lines" >&2
    exit 1
fi

python3 - "$line_file" "$ledger" <<'EOF'
import json, sys

line_file, ledger = sys.argv[1], sys.argv[2]
raw = open(line_file).read().strip()
try:
    entry = json.loads(raw)
except json.JSONDecodeError as e:
    sys.exit(f"error: artifact line is not valid JSON: {e}")

schema = entry.get("schema")
if schema != "pspice-scorecard-v1":
    sys.exit(f"error: unknown schema tag {schema!r} (expected pspice-scorecard-v1)")
if entry.get("smoke") is not False:
    sys.exit("error: the committed baseline must be a FULL run (smoke: false); "
             "this line is a smoke run")
h = entry.get("manifest_hash", "")
if not h.startswith("fnv1a:"):
    sys.exit(f"error: malformed manifest_hash {h!r}")
if not entry.get("cells"):
    sys.exit("error: ledger line carries no cells")

try:
    existing = [json.loads(l) for l in open(ledger) if l.strip()]
except FileNotFoundError:
    existing = []
for prev in existing:
    if prev.get("manifest_hash") == h and prev.get("smoke") is False \
            and prev.get("commit") == entry.get("commit"):
        sys.exit(f"error: an identical baseline ({h} @ {entry.get('commit')}) "
                 "is already committed")

with open(ledger, "a") as f:
    f.write(raw + "\n")
print(f"appended full-grid baseline {h} (commit {entry.get('commit', '?')}, "
      f"{len(entry['cells'])} cells) to SCORECARD.jsonl")
print("next: git add SCORECARD.jsonl && git commit")
EOF
